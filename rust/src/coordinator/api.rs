//! The unified sampler API: one spec, one output, one registry.
//!
//! The paper's framing is that SRDS, ParaDiGMS (Shih et al.) and ParaTAA
//! (Tang et al.) are interchangeable trajectory-parallel samplers over
//! the same probability-flow ODE. This module encodes that framing in
//! the type system:
//!
//! * [`SamplerSpec`] — one configuration type carrying the knobs every
//!   sampler shares (`n`, `tol`, `norm`, `max_iters`, `block`, `cond`,
//!   `seed`, `keep_iterates`) plus a [`SamplerKind`] with the per-kind
//!   parameters (ParaDiGMS sliding window, ParaTAA Anderson history).
//! * [`Sampler`] — the object-safe trait all samplers implement; every
//!   run returns the same [`SampleOutput`] (the sequential baseline gets
//!   a trivial adapter, so it is no longer a special case).
//! * [`registry`] — the single place that knows which samplers exist.
//!   The server, CLI, benches and examples all dispatch through it;
//!   adding a sampler means implementing the trait and registering it
//!   here — plus, if it should serve on the multi-tenant engine, an
//!   engine-native [`crate::exec::task::SamplerTask`] port (the serving
//!   path runs every registered kind as a dispatcher-resident state
//!   machine; `exec::task::new_task` is the kind → task map).

use super::convergence::ConvNorm;
use super::{Conditioning, RunStats};
use crate::schedule::Partition;
use crate::solvers::StepBackend;

/// Default ParaTAA Anderson history depth (Tang et al. use short
/// histories; 2 is this repo's bench setting).
pub const DEFAULT_HISTORY: usize = 2;

/// Quality-of-service priority class of a sampling request — the knob
/// the multi-tenant engine's weighted deficit-round-robin batcher
/// schedules by (`crate::batching::Batcher`). Classes shape *service
/// share under contention*, never numerics: a request's output is
/// identical whatever class it rides in.
///
/// On the wire this is the request's `"priority"` field
/// (`"interactive"` / `"standard"` / `"batch"`); library callers set it
/// with [`SamplerSpec::with_priority`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum QosClass {
    /// Latency-sensitive foreground traffic (a user is waiting).
    Interactive,
    /// The default class for unclassified requests.
    #[default]
    Standard,
    /// Throughput traffic that tolerates queueing (bulk generation,
    /// evals, backfills).
    Batch,
}

impl QosClass {
    /// Every class, in scheduling order (the DRR visit order).
    pub const ALL: [QosClass; 3] = [QosClass::Interactive, QosClass::Standard, QosClass::Batch];

    /// Canonical wire name.
    pub fn name(&self) -> &'static str {
        match self {
            QosClass::Interactive => "interactive",
            QosClass::Standard => "standard",
            QosClass::Batch => "batch",
        }
    }

    /// Parse a wire name (exact, lowercase).
    pub fn parse(s: &str) -> Option<QosClass> {
        QosClass::ALL.into_iter().find(|c| c.name() == s)
    }

    /// Dense index into per-class counter arrays (`[interactive,
    /// standard, batch]` — the [`QosClass::ALL`] order).
    pub fn index(&self) -> usize {
        *self as usize
    }
}

/// Which sampler to run, with its kind-specific parameters.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SamplerKind {
    /// The `N`-step sequential baseline (paper Eq. 3).
    Sequential,
    /// Self-Refining Diffusion Sampler, Algorithm 1.
    Srds,
    /// ParaDiGMS: Picard iteration with a sliding window
    /// (`None` → the full trajectory).
    Paradigms { window: Option<usize> },
    /// ParaTAA-style Anderson-accelerated fixed-point iteration
    /// (`history == 0` disables the acceleration).
    Parataa { history: usize },
}

impl SamplerKind {
    /// Canonical registry name of this kind.
    pub fn name(&self) -> &'static str {
        match self {
            SamplerKind::Sequential => "sequential",
            SamplerKind::Srds => "srds",
            SamplerKind::Paradigms { .. } => "paradigms",
            SamplerKind::Parataa { .. } => "parataa",
        }
    }

    /// Set the sliding window; a no-op for kinds without one.
    pub fn with_window(self, w: usize) -> Self {
        match self {
            SamplerKind::Paradigms { .. } => SamplerKind::Paradigms { window: Some(w) },
            other => other,
        }
    }

    /// Set the Anderson history depth; a no-op for kinds without one.
    pub fn with_history(self, h: usize) -> Self {
        match self {
            SamplerKind::Parataa { .. } => SamplerKind::Parataa { history: h },
            other => other,
        }
    }
}

/// Configuration for one sampling run — shared across every registered
/// sampler. Kind-specific parameters live in [`SamplerSpec::kind`];
/// samplers read knobs that don't apply to them as their defaults, so a
/// single spec can drive every entry of [`registry`] (that is what the
/// `samplers_agree_on_sample` tests do).
#[derive(Debug, Clone)]
pub struct SamplerSpec {
    /// Fine-grid steps `N`.
    pub n: usize,
    /// Fine steps per SRDS block (`None` → `⌈√N⌉`, the Prop. 4 optimum).
    pub block: Option<usize>,
    /// Convergence tolerance τ. SRDS and ParaTAA compare the
    /// `norm`-distance of the *final sample* between refinements against
    /// it (Alg. 1 line 13); ParaDiGMS compares its per-point mean
    /// *squared* update (which is how the paper's Table 4 thresholds
    /// 1e-3/1e-2/1e-1 are quoted — pass τ² to match them).
    pub tol: f32,
    /// Norm used for final-sample convergence checks.
    pub norm: ConvNorm,
    /// Iteration / sweep cap. `None` → each sampler's worst case
    /// (`num_blocks` for SRDS, `8·N` sweeps for ParaDiGMS, `2·N` for
    /// ParaTAA; ignored by the sequential baseline).
    pub max_iters: Option<usize>,
    /// Conditioning (guided models).
    pub cond: Conditioning,
    /// Seed for the DDPM noise derivation (ignored by ODE solvers).
    pub seed: u64,
    /// Keep the final-sample iterate after every refinement (Fig. 1/5/7).
    pub keep_iterates: bool,
    /// QoS priority class: the multi-tenant engine's weighted
    /// deficit-round-robin batcher schedules step rows by it. Never
    /// affects numerics — only service share under contention.
    pub priority: QosClass,
    /// Anytime eval budget: once a run has spent this many model
    /// evaluations, SRDS finalizes from its best *completed* iterate
    /// (reporting `converged: false` + the achieved residual) instead of
    /// refining further — graceful degradation under load, justified by
    /// the paper's §4 early-convergence property (every Parareal iterate
    /// is itself a valid approximate sample). Samplers without that
    /// serial-equivalence anchor (sequential, ParaDiGMS, ParaTAA) ignore
    /// the budget: truncating them mid-iteration has no quality
    /// guarantee to fall back on. `None` → run to convergence/cap.
    pub deadline_evals: Option<u64>,
    /// Per-request wall-clock timeout, enforced by the engine
    /// dispatcher. At expiry an SRDS run is finalized from its newest
    /// *completed* Parareal iterate (the same §4 anytime anchor as
    /// [`SamplerSpec::deadline_evals`], reported honestly via
    /// `RunStats::timed_out`); kinds without that anchor are failed with
    /// a timeout error instead. Enforced on serving submissions
    /// (`submit_serving`); blocking [`crate::exec::Engine::submit`]
    /// channels are simply dropped on a non-SRDS timeout. `None` → no
    /// wall-clock limit.
    pub timeout_ms: Option<u64>,
    /// Stream each completed iterate to the caller as it lands
    /// (serving-path `"stream": true`; SRDS only). Changes delivery,
    /// never numerics.
    pub stream: bool,
    /// Which sampler this spec targets, with its per-kind parameters.
    pub kind: SamplerKind,
}

impl SamplerSpec {
    /// A spec with the paper-default knobs and the given kind.
    pub fn for_kind(n: usize, kind: SamplerKind) -> Self {
        SamplerSpec {
            n,
            block: None,
            tol: 2.5e-3,
            norm: ConvNorm::L1Mean,
            max_iters: None,
            cond: Conditioning::none(),
            seed: 0,
            keep_iterates: false,
            priority: QosClass::Standard,
            deadline_evals: None,
            timeout_ms: None,
            stream: false,
            kind,
        }
    }

    /// Default spec: SRDS (the house sampler), paper-default knobs.
    pub fn new(n: usize) -> Self {
        Self::for_kind(n, SamplerKind::Srds)
    }

    pub fn sequential(n: usize) -> Self {
        Self::for_kind(n, SamplerKind::Sequential)
    }

    pub fn srds(n: usize) -> Self {
        Self::for_kind(n, SamplerKind::Srds)
    }

    pub fn paradigms(n: usize) -> Self {
        Self::for_kind(n, SamplerKind::Paradigms { window: None })
    }

    pub fn parataa(n: usize) -> Self {
        Self::for_kind(n, SamplerKind::Parataa { history: DEFAULT_HISTORY })
    }

    /// Range-check the knobs that would otherwise assert deep inside the
    /// schedule layer. Serving/CLI entry points call this before `run`
    /// so a malformed request is an error response, not a worker-thread
    /// panic; direct library callers that skip it keep the assert.
    pub fn validate(&self) -> Result<(), String> {
        if self.n == 0 {
            return Err("n must be >= 1".to_string());
        }
        if let Some(b) = self.block {
            if b == 0 || b > self.n {
                return Err(format!("block must be in 1..=n ({}), got {b}", self.n));
            }
        }
        Ok(())
    }

    /// The SRDS block partition this spec induces.
    pub fn partition(&self) -> Partition {
        match self.block {
            Some(b) => Partition::with_block(self.n, b),
            None => Partition::sqrt_n(self.n),
        }
    }

    /// ParaDiGMS sliding window (`None` unless the kind carries one).
    pub fn window(&self) -> Option<usize> {
        match self.kind {
            SamplerKind::Paradigms { window } => window,
            _ => None,
        }
    }

    /// ParaTAA Anderson history depth ([`DEFAULT_HISTORY`] unless the
    /// kind carries one).
    pub fn history(&self) -> usize {
        match self.kind {
            SamplerKind::Parataa { history } => history,
            _ => DEFAULT_HISTORY,
        }
    }

    /// Canonical identity of this spec's *numerics*: a stable FNV-1a
    /// hash over every field that can change a sample's value, with
    /// `None` defaults resolved before hashing so `block: None` and an
    /// explicit `with_block(⌈√n⌉)` — or `max_iters: None` and its
    /// per-kind default — collide on purpose. Two specs with equal
    /// `cache_key()` fed the same initial state produce bit-identical
    /// samples, which is what lets the engine coalesce concurrent
    /// duplicates and reuse cached coarse spines.
    ///
    /// Scheduling and payload knobs are deliberately **excluded**:
    /// `priority`, `deadline_evals`, `timeout_ms`, `stream`, and
    /// `keep_iterates` change when and how much work runs — or how its
    /// results are delivered — never the value of any computed state, so
    /// they must not fragment the key space. (The engine's in-flight
    /// coalescer re-adds the scheduling ones to its own key, because
    /// requests with different deadlines or payload shapes cannot share
    /// one task; streaming requests opt out of coalescing entirely.)
    pub fn cache_key(&self) -> u64 {
        let mut h = FNV_OFFSET;
        // Kind discriminant + the kind's own canonicalized parameters.
        match self.kind {
            SamplerKind::Sequential => h = fnv1a_u64(h, 0),
            SamplerKind::Srds => h = fnv1a_u64(h, 1),
            SamplerKind::Paradigms { .. } => {
                h = fnv1a_u64(h, 2);
                h = fnv1a_u64(h, self.window().unwrap_or(self.n).max(1) as u64);
            }
            SamplerKind::Parataa { .. } => {
                h = fnv1a_u64(h, 3);
                h = fnv1a_u64(h, self.history() as u64);
            }
        }
        h = fnv1a_u64(h, self.n as u64);
        // Default-filled block size: `partition()` resolves `None` to the
        // ⌈√n⌉ rule, so explicit-vs-implicit defaults hash identically.
        h = fnv1a_u64(h, self.partition().block() as u64);
        h = fnv1a_u64(h, u64::from(self.tol.to_bits()));
        h = fnv1a_u64(h, self.norm as u64);
        h = fnv1a_u64(h, self.effective_max_iters() as u64);
        h = fnv1a_u64(h, u64::from(self.cond.guidance.to_bits()));
        match self.cond.mask_slice() {
            None => h = fnv1a_u64(h, 0),
            Some(mask) => {
                h = fnv1a_u64(h, 1 + mask.len() as u64);
                for v in mask {
                    h = fnv1a_u64(h, u64::from(v.to_bits()));
                }
            }
        }
        fnv1a_u64(h, self.seed)
    }

    /// `max_iters` with each kind's own `None` default and clamp applied
    /// — the value the matching task/sampler actually iterates to, so
    /// `cache_key()` treats "default" and "explicitly the default" as
    /// the same spec. Sequential ignores the knob entirely and
    /// canonicalizes to 0.
    fn effective_max_iters(&self) -> usize {
        match self.kind {
            SamplerKind::Sequential => 0,
            SamplerKind::Srds => {
                let m = self.partition().num_blocks();
                self.max_iters.unwrap_or(m).max(1).min(m)
            }
            SamplerKind::Paradigms { .. } => self.max_iters.unwrap_or(8 * self.n).max(1),
            SamplerKind::Parataa { .. } => self.max_iters.unwrap_or(2 * self.n).max(1),
        }
    }

    pub fn with_kind(mut self, kind: SamplerKind) -> Self {
        self.kind = kind;
        self
    }

    pub fn with_tol(mut self, tol: f32) -> Self {
        self.tol = tol;
        self
    }

    pub fn with_norm(mut self, norm: ConvNorm) -> Self {
        self.norm = norm;
        self
    }

    pub fn with_block(mut self, block: usize) -> Self {
        self.block = Some(block);
        self
    }

    pub fn with_max_iters(mut self, k: usize) -> Self {
        self.max_iters = Some(k);
        self
    }

    pub fn with_cond(mut self, cond: Conditioning) -> Self {
        self.cond = cond;
        self
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn with_iterates(mut self) -> Self {
        self.keep_iterates = true;
        self
    }

    /// Set the QoS priority class (see [`SamplerSpec::priority`]).
    pub fn with_priority(mut self, class: QosClass) -> Self {
        self.priority = class;
        self
    }

    /// Set the anytime eval budget (see [`SamplerSpec::deadline_evals`]).
    pub fn with_deadline_evals(mut self, evals: u64) -> Self {
        self.deadline_evals = Some(evals);
        self
    }

    /// Set the wall-clock timeout (see [`SamplerSpec::timeout_ms`]).
    pub fn with_timeout_ms(mut self, ms: u64) -> Self {
        self.timeout_ms = Some(ms);
        self
    }

    /// Request per-iterate streaming (see [`SamplerSpec::stream`]).
    pub fn with_stream(mut self) -> Self {
        self.stream = true;
        self
    }

    /// Set the ParaDiGMS window (no-op unless `kind` is `Paradigms`).
    pub fn with_window(mut self, w: usize) -> Self {
        self.kind = self.kind.with_window(w);
        self
    }

    /// Set the ParaTAA history (no-op unless `kind` is `Parataa`).
    pub fn with_history(mut self, h: usize) -> Self {
        self.kind = self.kind.with_history(h);
        self
    }

    /// Run the sampler this spec's kind names, via [`registry`].
    pub fn run(&self, backend: &dyn StepBackend, x0: &[f32]) -> SampleOutput {
        registry()
            .parse(self.kind.name())
            .expect("every SamplerKind is registered")
            .run(backend, x0, self)
    }
}

/// FNV-1a 64-bit offset basis / prime — a fixed, dependency-free hash
/// whose value is stable across runs, platforms and compiler versions
/// (unlike `std::hash::DefaultHasher`, which is randomly keyed), so
/// [`SamplerSpec::cache_key`] can key caches that outlive a process.
const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// Fold one word into an FNV-1a state, byte by byte (little-endian).
fn fnv1a_u64(mut h: u64, v: u64) -> u64 {
    for b in v.to_le_bytes() {
        h = (h ^ u64::from(b)).wrapping_mul(FNV_PRIME);
    }
    h
}

/// Companion to [`SamplerSpec::cache_key`]: the same stable FNV-1a over
/// a state vector's f32 bit patterns. `(spec.cache_key(), state_hash(x0))`
/// is the full identity of a deterministic run — the engine's coalescer
/// and spine cache both key on the pair, and the router's affinity hint
/// reuses it so repeats land on the shard holding the cached spine.
pub fn state_hash(xs: &[f32]) -> u64 {
    let mut h = fnv1a_u64(FNV_OFFSET, xs.len() as u64);
    for v in xs {
        h = fnv1a_u64(h, u64::from(v.to_bits()));
    }
    h
}

/// What every sampler returns: the generated sample plus the shared
/// accounting. Replaces the per-sampler `{Srds,Paradigms,Parataa}Result`
/// trio.
#[derive(Debug, Clone)]
pub struct SampleOutput {
    /// The generated sample `x(s = 1)`.
    pub sample: Vec<f32>,
    /// Accounting (iterations, eval counts, convergence, memory).
    pub stats: RunStats,
    /// Final-sample iterate after every refinement — populated when
    /// `spec.keep_iterates` (SRDS also records the coarse init at
    /// index 0).
    pub iterates: Vec<Vec<f32>>,
}

/// A trajectory-parallel (or baseline) sampler. Object-safe; all
/// implementations run against [`StepBackend`], so they execute
/// identically over the native rust models and the AOT-compiled PJRT
/// artifacts.
///
/// This is the *direct* (single-tenant, blocking) face of a sampler.
/// On the serving path the same algorithms run as engine-native
/// [`crate::exec::task::SamplerTask`] state machines — bit-identical
/// outputs, pinned by the task drive-harness and mixed-fleet tests.
pub trait Sampler: Send + Sync {
    /// This sampler's kind with its default per-kind parameters.
    fn kind(&self) -> SamplerKind;
    /// Registry name (what the JSON protocol and CLI accept) — always
    /// the kind's canonical name, so the two can't drift apart.
    fn name(&self) -> &'static str {
        self.kind().name()
    }
    /// Run from the prior sample `x0` under `spec`.
    fn run(&self, backend: &dyn StepBackend, x0: &[f32], spec: &SamplerSpec) -> SampleOutput;
}

struct SequentialSampler;

impl Sampler for SequentialSampler {
    fn kind(&self) -> SamplerKind {
        SamplerKind::Sequential
    }

    fn run(&self, backend: &dyn StepBackend, x0: &[f32], spec: &SamplerSpec) -> SampleOutput {
        let (sample, stats) =
            super::sequential::sequential(backend, x0, spec.n, &spec.cond, spec.seed);
        let iterates = if spec.keep_iterates { vec![sample.clone()] } else { vec![] };
        SampleOutput { sample, stats, iterates }
    }
}

struct SrdsSampler;

impl Sampler for SrdsSampler {
    fn kind(&self) -> SamplerKind {
        SamplerKind::Srds
    }

    fn run(&self, backend: &dyn StepBackend, x0: &[f32], spec: &SamplerSpec) -> SampleOutput {
        super::srds::srds(backend, x0, spec)
    }
}

struct ParadigmsSampler;

impl Sampler for ParadigmsSampler {
    fn kind(&self) -> SamplerKind {
        SamplerKind::Paradigms { window: None }
    }

    fn run(&self, backend: &dyn StepBackend, x0: &[f32], spec: &SamplerSpec) -> SampleOutput {
        super::paradigms::paradigms(backend, x0, spec)
    }
}

struct ParataaSampler;

impl Sampler for ParataaSampler {
    fn kind(&self) -> SamplerKind {
        SamplerKind::Parataa { history: DEFAULT_HISTORY }
    }

    fn run(&self, backend: &dyn StepBackend, x0: &[f32], spec: &SamplerSpec) -> SampleOutput {
        super::parataa::parataa(backend, x0, spec)
    }
}

/// The set of registered samplers, in canonical order.
pub struct Registry {
    entries: Vec<Box<dyn Sampler>>,
}

impl Registry {
    /// Look a sampler up by its registry name.
    pub fn parse(&self, name: &str) -> Option<&dyn Sampler> {
        self.entries.iter().find(|s| s.name() == name).map(|s| s.as_ref())
    }

    /// Registered names, in registration order.
    pub fn list(&self) -> Vec<&'static str> {
        self.entries.iter().map(|s| s.name()).collect()
    }

    /// Iterate the registered samplers.
    pub fn iter(&self) -> impl Iterator<Item = &dyn Sampler> {
        self.entries.iter().map(|s| s.as_ref())
    }
}

/// Every sampler this crate knows about. Construction is cheap (the
/// samplers are stateless unit structs); call sites iterate a fresh
/// registry rather than hard-coding names.
pub fn registry() -> Registry {
    Registry {
        entries: vec![
            Box::new(SequentialSampler),
            Box::new(SrdsSampler),
            Box::new(ParadigmsSampler),
            Box::new(ParataaSampler),
        ],
    }
}

#[cfg(test)]
mod tests {
    use super::super::prior_sample;
    use super::*;
    use crate::data::make_gmm;
    use crate::model::GmmEps;
    use crate::solvers::{NativeBackend, Solver};
    use std::sync::Arc;

    fn backend() -> NativeBackend {
        NativeBackend::new(Arc::new(GmmEps::new(make_gmm("toy2d"))), Solver::Ddim)
    }

    #[test]
    fn registry_lists_all_four_samplers() {
        let reg = registry();
        assert_eq!(reg.list(), vec!["sequential", "srds", "paradigms", "parataa"]);
        for name in reg.list() {
            let s = reg.parse(name).expect("listed name parses");
            assert_eq!(s.name(), name);
            assert_eq!(s.kind().name(), name);
        }
        assert!(reg.parse("ddim").is_none());
        assert!(reg.parse("SRDS").is_none(), "names are case-sensitive");
    }

    #[test]
    fn config_defaults_follow_paper() {
        let spec = SamplerSpec::new(1024);
        let p = spec.partition();
        assert_eq!(p.block(), 32);
        assert_eq!(p.num_blocks(), 32);
        assert_eq!(spec.kind, SamplerKind::Srds);
    }

    #[test]
    fn kind_params_roundtrip_through_spec() {
        let spec = SamplerSpec::paradigms(64).with_window(16);
        assert_eq!(spec.window(), Some(16));
        assert_eq!(spec.history(), 2, "non-parataa specs report the default history");
        let spec = SamplerSpec::parataa(64).with_history(3);
        assert_eq!(spec.history(), 3);
        assert_eq!(spec.window(), None);
        // Kind-mismatched setters are no-ops, so one builder chain works
        // for every sampler.
        let spec = SamplerSpec::srds(64).with_window(16).with_history(3);
        assert_eq!(spec.kind, SamplerKind::Srds);
    }

    #[test]
    fn qos_class_names_roundtrip() {
        for c in QosClass::ALL {
            assert_eq!(QosClass::parse(c.name()), Some(c));
        }
        assert_eq!(QosClass::parse("INTERACTIVE"), None, "names are case-sensitive");
        assert_eq!(QosClass::parse("urgent"), None);
        assert_eq!(QosClass::default(), QosClass::Standard);
        // Dense indices cover 0..3 in ALL order (per-class counter arrays
        // are indexed by them).
        let idx: Vec<usize> = QosClass::ALL.iter().map(|c| c.index()).collect();
        assert_eq!(idx, vec![0, 1, 2]);
    }

    #[test]
    fn qos_knobs_ride_the_spec() {
        let spec = SamplerSpec::srds(16);
        assert_eq!(spec.priority, QosClass::Standard, "unclassified requests are standard");
        assert_eq!(spec.deadline_evals, None);
        let spec = spec.with_priority(QosClass::Interactive).with_deadline_evals(120);
        assert_eq!(spec.priority, QosClass::Interactive);
        assert_eq!(spec.deadline_evals, Some(120));
        assert!(spec.validate().is_ok(), "qos knobs never invalidate a spec");
    }

    #[test]
    fn cache_key_fills_defaults_before_hashing() {
        // `None` knobs hash as the value the sampler will actually use,
        // so "default" and "explicitly the default" are one cache line.
        assert_eq!(
            SamplerSpec::srds(25).cache_key(),
            SamplerSpec::srds(25).with_block(5).cache_key(),
            "block: None is the ⌈√n⌉ rule"
        );
        assert_eq!(
            SamplerSpec::srds(25).cache_key(),
            SamplerSpec::srds(25).with_max_iters(5).cache_key(),
            "max_iters: None is m for SRDS"
        );
        assert_eq!(
            SamplerSpec::paradigms(16).cache_key(),
            SamplerSpec::paradigms(16).with_window(16).cache_key(),
            "window: None is the full grid"
        );
        assert_eq!(
            SamplerSpec::paradigms(16).cache_key(),
            SamplerSpec::paradigms(16).with_max_iters(8 * 16).cache_key(),
            "max_iters: None is 8n for ParaDiGMS"
        );
        assert_eq!(
            SamplerSpec::parataa(16).cache_key(),
            SamplerSpec::parataa(16).with_history(DEFAULT_HISTORY).cache_key(),
        );
        // SRDS clamps max_iters to the block count, and the key follows
        // the clamp: asking for more iterations than blocks is the same
        // run as the default.
        assert_eq!(
            SamplerSpec::srds(25).cache_key(),
            SamplerSpec::srds(25).with_max_iters(99).cache_key(),
        );
    }

    #[test]
    fn cache_key_tracks_every_numerics_field() {
        // Each mutation below changes the computed sample, so each must
        // change the key — collect and demand all-distinct.
        let base = SamplerSpec::srds(25);
        let keys = vec![
            base.clone().cache_key(),
            SamplerSpec::srds(36).cache_key(),
            base.clone().with_block(4).cache_key(),
            base.clone().with_tol(1e-5).cache_key(),
            base.clone().with_norm(ConvNorm::LInf).cache_key(),
            base.clone().with_max_iters(1).cache_key(),
            base.clone().with_seed(1).cache_key(),
            base.clone().with_cond(Conditioning::class(vec![1.0, 0.0], 2.0)).cache_key(),
            base.clone().with_cond(Conditioning::class(vec![0.0, 1.0], 2.0)).cache_key(),
            SamplerSpec::sequential(25).cache_key(),
            SamplerSpec::paradigms(25).cache_key(),
            SamplerSpec::paradigms(25).with_window(5).cache_key(),
            SamplerSpec::parataa(25).cache_key(),
            SamplerSpec::parataa(25).with_history(3).cache_key(),
        ];
        let distinct: std::collections::HashSet<u64> = keys.iter().copied().collect();
        assert_eq!(distinct.len(), keys.len(), "a numerics field failed to reach the key");
    }

    #[test]
    fn cache_key_ignores_scheduling_and_payload_knobs() {
        // Priority, deadline budget, wall-clock timeout, streaming and
        // iterate retention steer *when* and *how much* work runs — or
        // how results are delivered — never what any state evaluates to,
        // so they must not fragment the spine cache.
        let base = SamplerSpec::srds(25).with_seed(3);
        let key = base.clone().cache_key();
        assert_eq!(key, base.clone().with_priority(QosClass::Interactive).cache_key());
        assert_eq!(key, base.clone().with_priority(QosClass::Batch).cache_key());
        assert_eq!(key, base.clone().with_deadline_evals(10).cache_key());
        assert_eq!(key, base.clone().with_iterates().cache_key());
        assert_eq!(key, base.clone().with_timeout_ms(5).cache_key());
        assert_eq!(key, base.clone().with_stream().cache_key());
    }

    #[test]
    fn state_hash_is_order_and_length_sensitive() {
        assert_eq!(state_hash(&[1.0, 2.0]), state_hash(&[1.0, 2.0]));
        assert_ne!(state_hash(&[1.0, 2.0]), state_hash(&[2.0, 1.0]));
        assert_ne!(state_hash(&[1.0]), state_hash(&[1.0, 0.0]));
        // f32 bit patterns, not values: -0.0 and 0.0 compare equal but
        // hash apart — the cache demands bit-identity, not equality.
        assert_ne!(state_hash(&[0.0]), state_hash(&[-0.0]));
    }

    #[test]
    fn validate_rejects_out_of_range_knobs() {
        assert!(SamplerSpec::new(0).validate().is_err());
        assert!(SamplerSpec::new(16).with_block(0).validate().is_err());
        assert!(SamplerSpec::new(16).with_block(17).validate().is_err());
        assert!(SamplerSpec::new(16).with_block(16).validate().is_ok());
        assert!(SamplerSpec::new(16).validate().is_ok());
    }

    #[test]
    fn samplers_agree_on_sample() {
        // The paper's interchangeability claim, enforced over the
        // registry: at tight tolerance every registered sampler produces
        // the sequential sample.
        let be = backend();
        let x0 = prior_sample(2, 9);
        let reg = registry();
        let reference = reg
            .parse("sequential")
            .unwrap()
            .run(&be, &x0, &SamplerSpec::sequential(25).with_seed(9))
            .sample;
        for name in reg.list() {
            let s = reg.parse(name).unwrap();
            let spec = SamplerSpec::for_kind(25, s.kind()).with_tol(1e-6).with_seed(9);
            let out = s.run(&be, &x0, &spec);
            let d = ConvNorm::L1Mean.dist(&out.sample, &reference);
            assert!(d < 1e-2, "{name} vs sequential: {d}");
            assert!(out.stats.total_evals > 0, "{name} reported no evals");
            assert!(out.stats.peak_states >= 1, "{name} reported no resident states");
        }
    }

    #[test]
    fn spec_run_dispatches_on_kind() {
        let be = backend();
        let x0 = prior_sample(2, 4);
        let spec = SamplerSpec::sequential(16).with_seed(4);
        let via_spec = spec.run(&be, &x0);
        let (direct, _) =
            super::super::sequential(&be, &x0, 16, &Conditioning::none(), 4);
        assert_eq!(via_spec.sample, direct);
    }

    #[test]
    fn keep_iterates_is_uniform_across_samplers() {
        let be = backend();
        let x0 = prior_sample(2, 7);
        let reg = registry();
        for name in reg.list() {
            let s = reg.parse(name).unwrap();
            let spec =
                SamplerSpec::for_kind(16, s.kind()).with_tol(1e-5).with_seed(7).with_iterates();
            let out = s.run(&be, &x0, &spec);
            assert!(!out.iterates.is_empty(), "{name} recorded no iterates");
            assert_eq!(
                out.iterates.last().unwrap(),
                &out.sample,
                "{name}: last iterate must be the returned sample"
            );
        }
    }
}
