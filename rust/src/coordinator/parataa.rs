//! ParaTAA-style baseline (Tang et al., "Accelerating Parallel Sampling
//! of Diffusion Models", App. E of the paper): fixed-point iteration on
//! the triangular trajectory system with Anderson acceleration.
//!
//! The sequential solve is the unique fixed point of
//! `T(X)_{i+1} = Φ(X_i)`, `T(X)_0 = x_0` over the stacked trajectory
//! `X ∈ R^{(N+1)·d}`. Plain fixed-point iteration converges in ≤ N
//! steps (triangular structure); Anderson mixing over a short residual
//! history accelerates it — the "triangular Anderson acceleration" idea.
//!
//! Spec knobs: the Anderson history depth comes from
//! [`SamplerKind::Parataa`](super::SamplerKind) (0 disables acceleration
//! → plain Picard on the full trajectory); convergence is declared when
//! the final sample moves less than `spec.tol` under `spec.norm`;
//! `spec.max_iters` caps the iterations (`None` → `2·N`).

use super::{IterStat, RunStats, SampleOutput, SamplerSpec, TiledMask};
use crate::buf::{BufPool, StateBuf};
use crate::schedule::Grid;
use crate::solvers::{StepBackend, StepRequest};
use std::collections::VecDeque;
use std::time::Instant;

/// Per-run staging for the trajectory map: grid times, seeds and the
/// tiled mask are constant across iterations, so they are built once
/// (the old code re-derived all four on every `T` application).
struct TrajSchedule {
    s_from: Vec<f32>,
    s_to: Vec<f32>,
    seeds: Vec<u64>,
    mask: TiledMask,
}

impl TrajSchedule {
    fn new(grid: &Grid, spec: &SamplerSpec) -> TrajSchedule {
        let n = grid.n();
        TrajSchedule {
            s_from: (0..n).map(|i| grid.s(i)).collect(),
            s_to: (0..n).map(|i| grid.s(i + 1)).collect(),
            seeds: vec![spec.seed; n],
            mask: spec.cond.tiler(n),
        }
    }
}

/// Apply the trajectory map `T`: one batched solver step at every grid
/// point, fed by the previous trajectory. Allocation-free: the stacked
/// trajectory is already the flat `(n, d)` batch input, and the step
/// writes straight into `out[d..]`.
fn apply_t(
    backend: &dyn StepBackend,
    sched: &TrajSchedule,
    x: &[f32], // (n+1, d) stacked
    guidance: f32,
    out: &mut [f32],
) {
    let n = sched.s_from.len();
    let d = backend.dim();
    out[..d].copy_from_slice(&x[..d]); // T(X)_0 = x_0
    backend.step_into(
        &StepRequest {
            x: &x[..n * d],
            s_from: &sched.s_from,
            s_to: &sched.s_to,
            mask: sched.mask.rows(n),
            guidance,
            seeds: &sched.seeds,
        },
        &mut out[d..(n + 1) * d],
    );
}

/// The Anderson-accelerated fixed-point update, factored out of the run
/// loop so the vanilla sampler below and the engine-resident
/// [`crate::exec::task`] sweep task share one bit-identical
/// implementation. Owns the (x, residual) history pairs (pooled
/// [`StateBuf`]s — once the window fills, the push/pop churn recycles
/// through the pool) and the mix scratch.
pub(crate) struct AndersonMixer {
    history: usize,
    hist_x: VecDeque<StateBuf>,
    hist_r: VecDeque<StateBuf>,
    xn: Vec<f32>,
}

impl AndersonMixer {
    pub(crate) fn new(history: usize, len: usize) -> AndersonMixer {
        AndersonMixer {
            history,
            hist_x: VecDeque::new(),
            hist_r: VecDeque::new(),
            xn: vec![0.0f32; len],
        }
    }

    fn push_hist(&mut self, x: &[f32], r: &[f32], pool: &BufPool) {
        self.hist_x.push_front(pool.take(x));
        self.hist_r.push_front(pool.take(r));
        if self.hist_x.len() > self.history {
            self.hist_x.pop_back();
            self.hist_r.pop_back();
        }
    }

    /// Advance the iterate `x` given its image `tx = T(x)` and residual
    /// `r = tx − x` at (1-based) iteration `k` — the Anderson-mixed
    /// update when the history supports it, the plain Picard step
    /// otherwise. The pre-update `(x, r)` pair enters the history either
    /// way.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn advance(
        &mut self,
        k: usize,
        n: usize,
        d: usize,
        x: &mut Vec<f32>,
        tx: &[f32],
        r: &[f32],
        pool: &BufPool,
    ) {
        let len = x.len();
        // Anderson mixing: minimize ‖r_k + Σ γ_j (r_{k-j} − r_k)‖ over the
        // history, then combine the corresponding T(x) iterates. Solved
        // via normal equations on the (tiny) history dimension.
        let mnow = self.hist_r.len().min(self.history);
        let gamma = if mnow > 0 {
            // Build difference vectors dR_j = r_hist[j] − r.
            let mut g = vec![0.0f64; mnow * mnow];
            let mut b = vec![0.0f64; mnow];
            for a in 0..mnow {
                let ra = &self.hist_r[a];
                for c in a..mnow {
                    let rc = &self.hist_r[c];
                    let mut dot = 0.0f64;
                    for t in 0..len {
                        dot += (ra[t] - r[t]) as f64 * (rc[t] - r[t]) as f64;
                    }
                    g[a * mnow + c] = dot;
                    g[c * mnow + a] = dot;
                }
                let mut dotb = 0.0f64;
                for t in 0..len {
                    dotb += (ra[t] - r[t]) as f64 * (-r[t]) as f64;
                }
                b[a] = dotb;
            }
            // Tikhonov-regularized solve (history ≤ 3 → direct Gauss).
            for a in 0..mnow {
                g[a * mnow + a] += 1e-10 + 1e-8 * g[a * mnow + a];
            }
            solve_small(&mut g, &mut b, mnow).filter(|gm| {
                // Safeguard: reject wild extrapolations (large mixing
                // weights amplify the strongly non-normal triangular
                // dynamics); fall back to the plain Picard step.
                gm.iter().map(|v| v.abs()).sum::<f64>() <= 1.0
            })
        } else {
            None
        };
        if let Some(gamma) = gamma {
            // x_next = T(x) + Σ γ_j (T(x_hist_j) − T(x)) — with the
            // standard identity T(x_j) = x_j + r_j.
            self.xn.copy_from_slice(tx);
            // Triangular awareness (the "TAA" in ParaTAA): after k
            // plain applications of T the first k+1 trajectory points
            // are *exactly* converged; mixing stale history there
            // would destroy the finite-convergence property, so the
            // accelerated update only touches the unconverged tail.
            let prefix = (k + 1).min(n + 1) * d;
            for (j, &gj) in gamma.iter().enumerate() {
                let xa = &self.hist_x[j];
                let ra = &self.hist_r[j];
                let gj = gj as f32;
                for t in prefix..len {
                    self.xn[t] += gj * ((xa[t] + ra[t]) - tx[t]);
                }
            }
            self.push_hist(x, r, pool);
            // xn becomes the iterate; the old iterate's buffer stays
            // around as next round's mix scratch.
            std::mem::swap(x, &mut self.xn);
        } else {
            self.push_hist(x, r, pool);
            x.copy_from_slice(tx);
        }
    }
}

/// Run the Anderson-accelerated fixed-point sampler.
///
/// Zero-copy layout: the trajectory iterate, its `T`-image, the residual
/// and the Anderson-mix scratch are persistent flat buffers; the history
/// pairs are pooled [`StateBuf`]s, so once the history window fills the
/// push/pop churn recycles through the pool instead of allocating.
pub fn parataa(backend: &dyn StepBackend, x0: &[f32], spec: &SamplerSpec) -> SampleOutput {
    let t0 = Instant::now();
    let n = spec.n;
    let d = backend.dim();
    let grid = Grid::new(n);
    let epc = backend.evals_per_step() as u64;
    let len = (n + 1) * d;
    let history = spec.history();
    let max_iters = spec.max_iters.unwrap_or(2 * n).max(1);
    let sched = TrajSchedule::new(&grid, spec);
    let pool = BufPool::new();

    // Initialize the trajectory at the prior (as ParaDiGMS does).
    let mut x = vec![0.0f32; len];
    for i in 0..=n {
        x[i * d..(i + 1) * d].copy_from_slice(x0);
    }
    let mut tx = vec![0.0f32; len];
    let mut r = vec![0.0f32; len];
    let mut mixer = AndersonMixer::new(history, len);

    let mut total_evals = 0u64;
    let mut per_iter = Vec::new();
    let mut iterates = Vec::new();
    let mut converged = false;
    let mut iters = 0usize;

    for k in 1..=max_iters {
        apply_t(backend, &sched, &x, spec.cond.guidance, &mut tx);
        total_evals += n as u64 * epc;
        for t in 0..len {
            r[t] = tx[t] - x[t];
        }

        // Residual on the final sample only (matches the SRDS criterion).
        let final_res = spec.norm.dist(&tx[n * d..], &x[n * d..]);
        iters = k;
        per_iter.push(IterStat { iter: k, residual: final_res, evals: n as u64 * epc });

        if final_res < spec.tol {
            x.copy_from_slice(&tx);
            if spec.keep_iterates {
                iterates.push(x[n * d..].to_vec());
            }
            converged = true;
            break;
        }

        mixer.advance(k, n, d, &mut x, &tx, &r, &pool);
        if spec.keep_iterates {
            iterates.push(x[n * d..].to_vec());
        }
    }

    let ps = pool.stats();
    let stats = RunStats {
        iters,
        converged,
        deadline_hit: false,
        timed_out: false,
        eff_serial_evals: iters as u64 * epc,
        eff_serial_evals_pipelined: iters as u64 * epc,
        total_evals,
        wall: t0.elapsed(),
        // Whole-trajectory iterate, its T-image, the residual, and the
        // Anderson history pairs — the O(N·history) memory of §3.6.
        peak_states: (n + 1) * (3 + 2 * history),
        batch_occupancy: 0.0,
        engine_rows: 0,
        pool_hits: ps.hits,
        pool_misses: ps.misses,
        per_iter,
    };
    SampleOutput { sample: x[n * d..].to_vec(), stats, iterates }
}

/// Gaussian elimination for the tiny Anderson system (m ≤ ~4).
fn solve_small(g: &mut [f64], b: &mut [f64], m: usize) -> Option<Vec<f64>> {
    for col in 0..m {
        // partial pivot
        let mut piv = col;
        for r in col + 1..m {
            if g[r * m + col].abs() > g[piv * m + col].abs() {
                piv = r;
            }
        }
        if g[piv * m + col].abs() < 1e-300 {
            return None;
        }
        if piv != col {
            for c in 0..m {
                g.swap(col * m + c, piv * m + c);
            }
            b.swap(col, piv);
        }
        let diag = g[col * m + col];
        for r in col + 1..m {
            let f = g[r * m + col] / diag;
            for c in col..m {
                g[r * m + c] -= f * g[col * m + c];
            }
            b[r] -= f * b[col];
        }
    }
    let mut out = vec![0.0f64; m];
    for col in (0..m).rev() {
        let mut acc = b[col];
        for c in col + 1..m {
            acc -= g[col * m + c] * out[c];
        }
        out[col] = acc / g[col * m + col];
    }
    Some(out)
}

#[cfg(test)]
mod tests {
    use super::super::{prior_sample, sequential, Conditioning, SamplerSpec};
    use super::*;
    use crate::data::make_gmm;
    use crate::model::GmmEps;
    use crate::solvers::{NativeBackend, Solver};
    use std::sync::Arc;

    fn backend() -> NativeBackend {
        NativeBackend::new(Arc::new(GmmEps::new(make_gmm("toy2d"))), Solver::Ddim)
    }

    #[test]
    fn converges_to_sequential() {
        let be = backend();
        let x0 = prior_sample(2, 31);
        let (seq, _) = sequential(&be, &x0, 25, &Conditioning::none(), 31);
        let res = parataa(&be, &x0, &SamplerSpec::parataa(25).with_tol(1e-4).with_seed(31));
        assert!(res.stats.converged, "iters {}", res.stats.iters);
        let d: f32 = seq.iter().zip(&res.sample).map(|(a, b)| (a - b).abs()).sum::<f32>() / 2.0;
        assert!(d < 5e-3, "parataa vs sequential {d}");
    }

    #[test]
    fn anderson_accelerates_over_plain_picard() {
        let be = backend();
        let x0 = prior_sample(2, 8);
        let plain = parataa(
            &be,
            &x0,
            &SamplerSpec::parataa(64).with_history(0).with_tol(1e-4).with_seed(8),
        );
        let acc = parataa(
            &be,
            &x0,
            &SamplerSpec::parataa(64).with_history(2).with_tol(1e-4).with_seed(8),
        );
        assert!(
            acc.stats.iters <= plain.stats.iters,
            "anderson {} vs plain {}",
            acc.stats.iters,
            plain.stats.iters
        );
    }

    #[test]
    fn fewer_serial_steps_than_sequential() {
        // Early convergence on a higher-dim dataset (the 2-d toy's final
        // point keeps drifting and needs nearly all N sweeps at tight
        // tolerances — see the bench sweeps for the full picture).
        let be = NativeBackend::new(
            Arc::new(GmmEps::new(make_gmm("church"))),
            Solver::Ddim,
        );
        let x0 = prior_sample(64, 4);
        let res = parataa(&be, &x0, &SamplerSpec::parataa(100).with_tol(1e-3).with_seed(4));
        assert!(res.stats.converged);
        assert!(res.stats.eff_serial_evals < 100, "evals {}", res.stats.eff_serial_evals);
    }

    #[test]
    fn solve_small_solves_2x2() {
        let mut g = vec![4.0, 1.0, 1.0, 3.0];
        let mut b = vec![1.0, 2.0];
        let x = solve_small(&mut g, &mut b, 2).unwrap();
        assert!((4.0 * x[0] + x[1] - 1.0).abs() < 1e-12);
        assert!((x[0] + 3.0 * x[1] - 2.0).abs() < 1e-12);
    }
}
