//! ParaDiGMS baseline (Shih et al., "Parallel Sampling of Diffusion
//! Models") — Picard iteration over the fine trajectory with a sliding
//! window.
//!
//! Each parallel sweep evaluates the solver step at every point of the
//! current window from the *previous* trajectory iterate and rebuilds the
//! window by prefix-summing the drifts:
//!
//! ```text
//! x^{k+1}_{j+1} = x_lo + Σ_{u=lo..j} (Φ(x^k_u) − x^k_u)
//! ```
//!
//! The window start advances past points whose update fell below the
//! per-point tolerance. Memory is O(window) trajectory states — the
//! O(N)-vs-O(√N) contrast of paper §3.6 — and every sweep needs a
//! cross-device prefix sum (the communication cost App. D discusses).
//!
//! Spec knobs: the sliding window comes from
//! [`SamplerKind::Paradigms`](super::SamplerKind); `spec.tol` is the
//! per-point mean *squared* update threshold (ParaDiGMS compares squared
//! error against its τ, which is how the paper's Table 4 thresholds
//! 1e-3 / 1e-2 / 1e-1 are quoted); `spec.max_iters` caps the parallel
//! sweeps (`None` → `8·N`).

use super::{IterStat, RunStats, SampleOutput, SamplerSpec};
use crate::buf::{BatchStage, BufPool, StateBuf};
use crate::schedule::Grid;
use crate::solvers::StepBackend;
use std::time::Instant;

/// One window point's Picard rebuild: fold the point's drift
/// `Φ(x^k_j) − x^k_j` into the running prefix sum `acc` and return the
/// per-point mean *squared* update `‖acc − x^k_{j+1}‖²/d` (ParaDiGMS's
/// convergence quantity; `acc` afterwards holds the new `x^{k+1}_{j+1}`).
/// Shared by the vanilla sweep below and the engine-resident
/// [`crate::exec::task`] sweep task so the two paths cannot drift.
#[inline]
pub(crate) fn picard_point_update(
    acc: &mut [f32],
    phi: &[f32],
    xin: &[f32],
    x_next: &[f32],
) -> f32 {
    let mut err = 0.0f32;
    for t in 0..acc.len() {
        acc[t] += phi[t] - xin[t];
        let delta = acc[t] - x_next[t];
        err += delta * delta;
    }
    err / acc.len() as f32
}

/// Run ParaDiGMS from the prior sample `x0`.
///
/// Zero-copy layout: the trajectory points are pooled [`StateBuf`]s
/// written in place, every sweep's window is staged through one reused
/// [`BatchStage`] (whose staged inputs double as the pre-sweep `x^k`
/// values the drift rebuild needs), and the prefix-sum accumulator is a
/// single persistent buffer — sweeps past the first allocate nothing.
pub fn paradigms(backend: &dyn StepBackend, x0: &[f32], spec: &SamplerSpec) -> SampleOutput {
    let t0 = Instant::now();
    let n = spec.n;
    let d = backend.dim();
    let grid = Grid::new(n);
    let epc = backend.evals_per_step() as u64;
    let window = spec.window().unwrap_or(n).max(1);
    let max_sweeps = spec.max_iters.unwrap_or(8 * n).max(1);

    // Trajectory x[0..=n]; ParaDiGMS initializes every point to x0.
    let pool = BufPool::new();
    let mut x: Vec<StateBuf> = (0..=n).map(|_| pool.take(x0)).collect();
    let mut stage = BatchStage::new();
    let mut acc = vec![0.0f32; d];
    let mut lo = 0usize;
    let mut total_evals = 0u64;
    let mut sweeps = 0usize;
    let mut per_iter = Vec::new();
    let mut iterates = Vec::new();
    let tol2 = spec.tol; // squared-error threshold (see module docs)

    while lo < n && sweeps < max_sweeps {
        let hi = (lo + window).min(n);
        let rows = hi - lo;
        // Batched parallel evaluation of Φ at every window point.
        stage.reset(spec.cond.guidance);
        for (j, xj) in x.iter().enumerate().take(hi).skip(lo) {
            stage.push_row(xj, grid.s(j), grid.s(j + 1), spec.seed, spec.cond.mask_slice());
        }
        stage.execute(backend);
        total_evals += rows as u64 * epc;
        sweeps += 1;

        // Prefix-sum rebuild + per-point error.
        acc.copy_from_slice(&x[lo]);
        let mut first_unconverged = hi; // index past lo of first bad point
        let mut max_err = 0.0f32;
        // Drift is Φ(x^k_j) − x^k_j on the *pre-sweep* trajectory — the
        // stage's staged inputs still hold it (x[j] may already be
        // overwritten below).
        let (xin, phi) = (stage.x(), stage.out());
        for j in lo..hi {
            let base = (j - lo) * d;
            let err = picard_point_update(
                &mut acc,
                &phi[base..base + d],
                &xin[base..base + d],
                &x[j + 1],
            );
            max_err = max_err.max(err);
            x[j + 1].as_mut_slice().copy_from_slice(&acc);
            if err > tol2 && first_unconverged == hi {
                first_unconverged = j;
            }
        }
        // Advance past converged prefix (always ≥ 1 to guarantee progress:
        // the first window point is a fixed-input Picard update and is
        // exact after its first evaluation, mirroring the reference impl).
        let stride = (first_unconverged - lo).max(1);
        per_iter.push(IterStat { iter: sweeps, residual: max_err.sqrt(), evals: rows as u64 * epc });
        if spec.keep_iterates {
            iterates.push(x[n].to_vec());
        }
        lo += stride;
    }

    let ps = pool.stats();
    let stats = RunStats {
        iters: sweeps,
        converged: lo >= n,
        deadline_hit: false,
        timed_out: false,
        eff_serial_evals: sweeps as u64 * epc,
        eff_serial_evals_pipelined: sweeps as u64 * epc,
        total_evals,
        wall: t0.elapsed(),
        // The window of live trajectory states plus the window anchor —
        // the O(window) memory of the §3.6 comparison.
        peak_states: window.min(n) + 1,
        batch_occupancy: 0.0,
        engine_rows: 0,
        pool_hits: ps.hits,
        pool_misses: ps.misses,
        per_iter,
    };
    SampleOutput { sample: x.pop().unwrap().into_vec(), stats, iterates }
}

#[cfg(test)]
mod tests {
    use super::super::{prior_sample, sequential, Conditioning, SamplerSpec};
    use super::*;
    use crate::data::make_gmm;
    use crate::model::GmmEps;
    use crate::solvers::{NativeBackend, Solver};
    use std::sync::Arc;

    fn backend() -> NativeBackend {
        NativeBackend::new(Arc::new(GmmEps::new(make_gmm("toy2d"))), Solver::Ddim)
    }

    #[test]
    fn tight_tolerance_matches_sequential() {
        let be = backend();
        let x0 = prior_sample(2, 17);
        let (seq, _) = sequential(&be, &x0, 25, &Conditioning::none(), 17);
        let res = paradigms(&be, &x0, &SamplerSpec::paradigms(25).with_tol(1e-5).with_seed(17));
        assert!(res.stats.converged);
        let d: f32 =
            seq.iter().zip(&res.sample).map(|(a, b)| (a - b).abs()).sum::<f32>() / 2.0;
        assert!(d < 1e-2, "paradigms vs sequential {d}");
    }

    #[test]
    fn parallel_sweeps_fewer_than_n() {
        // The whole point: effective serial evals << N.
        let be = backend();
        let x0 = prior_sample(2, 3);
        let res = paradigms(&be, &x0, &SamplerSpec::paradigms(100).with_tol(1e-3).with_seed(3));
        assert!(res.stats.converged);
        assert!(
            res.stats.eff_serial_evals < 100,
            "sweeps {} not < N",
            res.stats.eff_serial_evals
        );
    }

    #[test]
    fn windowed_run_bounds_memory() {
        let be = backend();
        let x0 = prior_sample(2, 5);
        let res = paradigms(
            &be,
            &x0,
            &SamplerSpec::paradigms(64).with_tol(1e-4).with_window(16).with_seed(5),
        );
        assert!(res.stats.converged);
        assert_eq!(res.stats.peak_states, 17);
    }

    #[test]
    fn looser_tolerance_is_cheaper() {
        let be = backend();
        let x0 = prior_sample(2, 9);
        let tight = paradigms(&be, &x0, &SamplerSpec::paradigms(64).with_tol(1e-4).with_seed(9));
        let loose = paradigms(&be, &x0, &SamplerSpec::paradigms(64).with_tol(1e-1).with_seed(9));
        assert!(loose.stats.eff_serial_evals <= tight.stats.eff_serial_evals);
    }
}
