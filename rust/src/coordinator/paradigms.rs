//! ParaDiGMS baseline (Shih et al., "Parallel Sampling of Diffusion
//! Models") — Picard iteration over the fine trajectory with a sliding
//! window.
//!
//! Each parallel sweep evaluates the solver step at every point of the
//! current window from the *previous* trajectory iterate and rebuilds the
//! window by prefix-summing the drifts:
//!
//! ```text
//! x^{k+1}_{j+1} = x_lo + Σ_{u=lo..j} (Φ(x^k_u) − x^k_u)
//! ```
//!
//! The window start advances past points whose update fell below the
//! per-point tolerance. Memory is O(window) trajectory states — the
//! O(N)-vs-O(√N) contrast of paper §3.6 — and every sweep needs a
//! cross-device prefix sum (the communication cost App. D discusses).
//!
//! Spec knobs: the sliding window comes from
//! [`SamplerKind::Paradigms`](super::SamplerKind); `spec.tol` is the
//! per-point mean *squared* update threshold (ParaDiGMS compares squared
//! error against its τ, which is how the paper's Table 4 thresholds
//! 1e-3 / 1e-2 / 1e-1 are quoted); `spec.max_iters` caps the parallel
//! sweeps (`None` → `8·N`).

use super::{IterStat, RunStats, SampleOutput, SamplerSpec};
use crate::schedule::Grid;
use crate::solvers::{StepBackend, StepRequest};
use std::time::Instant;

/// Run ParaDiGMS from the prior sample `x0`.
pub fn paradigms(backend: &dyn StepBackend, x0: &[f32], spec: &SamplerSpec) -> SampleOutput {
    let t0 = Instant::now();
    let n = spec.n;
    let d = backend.dim();
    let grid = Grid::new(n);
    let epc = backend.evals_per_step() as u64;
    let window = spec.window().unwrap_or(n).max(1);
    let max_sweeps = spec.max_iters.unwrap_or(8 * n).max(1);

    // Trajectory x[0..=n]; ParaDiGMS initializes every point to x0.
    let mut x: Vec<Vec<f32>> = vec![x0.to_vec(); n + 1];
    let mut lo = 0usize;
    let mut total_evals = 0u64;
    let mut sweeps = 0usize;
    let mut per_iter = Vec::new();
    let mut iterates = Vec::new();
    let tol2 = spec.tol; // squared-error threshold (see module docs)

    while lo < n && sweeps < max_sweeps {
        let hi = (lo + window).min(n);
        let rows = hi - lo;
        // Batched parallel evaluation of Φ at every window point.
        let mut xin = Vec::with_capacity(rows * d);
        let mut s_from = Vec::with_capacity(rows);
        let mut s_to = Vec::with_capacity(rows);
        for j in lo..hi {
            xin.extend_from_slice(&x[j]);
            s_from.push(grid.s(j));
            s_to.push(grid.s(j + 1));
        }
        let mask = spec.cond.tiled_mask(rows);
        let seeds = vec![spec.seed; rows];
        let phi = backend.step(&StepRequest {
            x: &xin,
            s_from: &s_from,
            s_to: &s_to,
            mask: mask.as_deref(),
            guidance: spec.cond.guidance,
            seeds: &seeds,
        });
        total_evals += rows as u64 * epc;
        sweeps += 1;

        // Prefix-sum rebuild + per-point error.
        let mut acc = x[lo].clone();
        let mut first_unconverged = hi; // index past lo of first bad point
        let mut max_err = 0.0f32;
        for j in lo..hi {
            let drift_base = (j - lo) * d;
            let mut err = 0.0f32;
            // Drift is Φ(x^k_j) − x^k_j on the *pre-sweep* trajectory —
            // `xin` still holds it (x[j] may already be overwritten).
            for t in 0..d {
                acc[t] += phi[drift_base + t] - xin[drift_base + t];
                let delta = acc[t] - x[j + 1][t];
                err += delta * delta;
            }
            err /= d as f32;
            max_err = max_err.max(err);
            x[j + 1].copy_from_slice(&acc);
            if err > tol2 && first_unconverged == hi {
                first_unconverged = j;
            }
        }
        // Advance past converged prefix (always ≥ 1 to guarantee progress:
        // the first window point is a fixed-input Picard update and is
        // exact after its first evaluation, mirroring the reference impl).
        let stride = (first_unconverged - lo).max(1);
        per_iter.push(IterStat { iter: sweeps, residual: max_err.sqrt(), evals: rows as u64 * epc });
        if spec.keep_iterates {
            iterates.push(x[n].clone());
        }
        lo += stride;
    }

    let stats = RunStats {
        iters: sweeps,
        converged: lo >= n,
        eff_serial_evals: sweeps as u64 * epc,
        eff_serial_evals_pipelined: sweeps as u64 * epc,
        total_evals,
        wall: t0.elapsed(),
        // The window of live trajectory states plus the window anchor —
        // the O(window) memory of the §3.6 comparison.
        peak_states: window.min(n) + 1,
        batch_occupancy: 0.0,
        engine_rows: 0,
        per_iter,
    };
    SampleOutput { sample: x[n].clone(), stats, iterates }
}

#[cfg(test)]
mod tests {
    use super::super::{prior_sample, sequential, Conditioning, SamplerSpec};
    use super::*;
    use crate::data::make_gmm;
    use crate::model::GmmEps;
    use crate::solvers::{NativeBackend, Solver};
    use std::sync::Arc;

    fn backend() -> NativeBackend {
        NativeBackend::new(Arc::new(GmmEps::new(make_gmm("toy2d"))), Solver::Ddim)
    }

    #[test]
    fn tight_tolerance_matches_sequential() {
        let be = backend();
        let x0 = prior_sample(2, 17);
        let (seq, _) = sequential(&be, &x0, 25, &Conditioning::none(), 17);
        let res = paradigms(&be, &x0, &SamplerSpec::paradigms(25).with_tol(1e-5).with_seed(17));
        assert!(res.stats.converged);
        let d: f32 =
            seq.iter().zip(&res.sample).map(|(a, b)| (a - b).abs()).sum::<f32>() / 2.0;
        assert!(d < 1e-2, "paradigms vs sequential {d}");
    }

    #[test]
    fn parallel_sweeps_fewer_than_n() {
        // The whole point: effective serial evals << N.
        let be = backend();
        let x0 = prior_sample(2, 3);
        let res = paradigms(&be, &x0, &SamplerSpec::paradigms(100).with_tol(1e-3).with_seed(3));
        assert!(res.stats.converged);
        assert!(
            res.stats.eff_serial_evals < 100,
            "sweeps {} not < N",
            res.stats.eff_serial_evals
        );
    }

    #[test]
    fn windowed_run_bounds_memory() {
        let be = backend();
        let x0 = prior_sample(2, 5);
        let res = paradigms(
            &be,
            &x0,
            &SamplerSpec::paradigms(64).with_tol(1e-4).with_window(16).with_seed(5),
        );
        assert!(res.stats.converged);
        assert_eq!(res.stats.peak_states, 17);
    }

    #[test]
    fn looser_tolerance_is_cheaper() {
        let be = backend();
        let x0 = prior_sample(2, 9);
        let tight = paradigms(&be, &x0, &SamplerSpec::paradigms(64).with_tol(1e-4).with_seed(9));
        let loose = paradigms(&be, &x0, &SamplerSpec::paradigms(64).with_tol(1e-1).with_seed(9));
        assert!(loose.stats.eff_serial_evals <= tight.stats.eff_serial_evals);
    }
}
