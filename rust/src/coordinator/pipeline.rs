//! Pipelined SRDS (paper §3.4, Fig. 4): the dependency-graph schedule.
//!
//! Pipelining does not change the iterates — `F(x^p_i)` and `G(x^p_i)`
//! depend only on `x^p_i`, so iteration `p+1`'s fine solve for block `i`
//! can start as soon as `x^p_{i-1}` exists, long before iteration `p`'s
//! sweep finishes (Fig. 3). This module computes the *ideal* (unbounded
//! devices) schedule from the dependency recurrence used in the Prop. 2
//! proof; [`crate::exec::simclock`] schedules the same task graph under a
//! bounded device count.

use crate::schedule::Partition;

/// Task kinds in the SRDS dependency graph.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TaskKind {
    /// `G` step `i` of refinement `p` (`p = 0` is the init sweep).
    Coarse,
    /// `F` block solve `i` of refinement `p ≥ 1` (block_len steps).
    Fine,
}

/// One scheduled task, in model-evaluation time units.
#[derive(Debug, Clone)]
pub struct TaskSpan {
    pub kind: TaskKind,
    /// Refinement iteration `p` (0 = init sweep, fine tasks start at 1).
    pub iter: usize,
    /// Block index `i ∈ [1, M]`.
    pub block: usize,
    pub start: u64,
    pub end: u64,
}

/// The ideal pipelined schedule for `iters` refinements.
#[derive(Debug, Clone)]
pub struct PipelineStats {
    /// Time (effective serial evals) at which `x^{iters}_M` is ready.
    pub finish: u64,
    /// Peak number of simultaneously running model evaluations
    /// (Prop. 3: ≤ M + 1).
    pub peak_concurrency: usize,
    pub tasks: Vec<TaskSpan>,
}

/// Compute the ideal pipelined schedule.
///
/// Recurrence (eval units, `epc` = evals per solver step):
/// ```text
/// X[p][0]   = 0                                  (x_0 is the prior)
/// X[0][i]   = X[0][i-1] + epc                    (init coarse sweep)
/// F(p,i)    : start X[p-1][i-1], len block_len(i)·epc
/// G(p,i)    : start X[p][i-1],   len epc
/// X[p][i]   = max(F(p,i).end, G(p,i).end, X[p-1][i])
/// ```
pub fn pipeline_schedule(part: &Partition, iters: usize, epc: u64) -> PipelineStats {
    let m = part.num_blocks();
    let mut tasks = Vec::new();
    // X[p][i] ready times.
    let mut x_prev: Vec<u64> = vec![0; m + 1]; // X[p-1][·]
    for i in 1..=m {
        let start = x_prev[i - 1];
        let end = start + epc;
        tasks.push(TaskSpan { kind: TaskKind::Coarse, iter: 0, block: i, start, end });
        x_prev[i] = end;
    }
    for p in 1..=iters {
        let mut x_cur: Vec<u64> = vec![0; m + 1];
        for i in 1..=m {
            // Prop. 1 prefix convergence: by iteration p the first p
            // boundary states are final, so the efficient implementation
            // reuses the cached F/G results there instead of recomputing
            // (this is also what keeps concurrency at O(M), Prop. 3).
            if i < p {
                x_cur[i] = x_prev[i];
                continue;
            }
            let f_start = x_prev[i - 1];
            let f_end = f_start + part.block_len(i - 1) as u64 * epc;
            tasks.push(TaskSpan { kind: TaskKind::Fine, iter: p, block: i, start: f_start, end: f_end });
            // G(p, i) recomputes only where x^p_{i-1} changed (i ≥ p + 1);
            // for i == p the correction cancels bitwise and x^p_p = y_p.
            let g_end = if i > p {
                let g_start = x_cur[i - 1];
                let g_end = g_start + epc;
                tasks.push(TaskSpan { kind: TaskKind::Coarse, iter: p, block: i, start: g_start, end: g_end });
                g_end
            } else {
                0
            };
            x_cur[i] = f_end.max(g_end).max(x_prev[i]);
        }
        x_prev = x_cur;
    }
    let finish = x_prev[m];
    let peak = peak_concurrency(&tasks);
    PipelineStats { finish, peak_concurrency: peak, tasks }
}

/// Peak number of overlapping tasks (each task = one device-resident
/// model-evaluation stream).
fn peak_concurrency(tasks: &[TaskSpan]) -> usize {
    let mut events: Vec<(u64, i32)> = Vec::with_capacity(tasks.len() * 2);
    for t in tasks {
        if t.end > t.start {
            events.push((t.start, 1));
            events.push((t.end, -1));
        }
    }
    events.sort();
    let mut cur = 0i32;
    let mut peak = 0i32;
    for (_, d) in events {
        cur += d;
        peak = peak.max(cur);
    }
    peak.max(0) as usize
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_prop2_closed_form_on_uniform_partitions() {
        // finish(p) = M·p + B − p (epc = 1), the Prop. 2 proof quantity.
        for (n, b) in [(25usize, 5usize), (961, 31), (196, 14), (1024, 32)] {
            let part = Partition::with_block(n, b);
            let m = part.num_blocks();
            for p in 1..=4usize {
                let st = pipeline_schedule(&part, p, 1);
                assert_eq!(st.finish, (m * p + b - p) as u64, "n={n} p={p}");
            }
        }
    }

    #[test]
    fn worst_case_is_sequential_time() {
        // Prop. 2: running all M refinements costs exactly N eval units.
        for n in [16usize, 25, 144] {
            let part = Partition::sqrt_n(n);
            let st = pipeline_schedule(&part, part.num_blocks(), 1);
            assert_eq!(st.finish, n as u64, "n={n}");
        }
    }

    #[test]
    fn peak_concurrency_is_order_sqrt_n() {
        // Prop. 3: O(√N) concurrent model evaluations. The *ideal*
        // schedule briefly overlaps a block's fine solves from adjacent
        // iterations (that overlap is what realizes the Prop. 2 finish
        // time), so the exact bound is 2M + 1 rather than M + 1 — still
        // O(√N), vs ParaDiGMS's O(N).
        for n in [25usize, 100, 196] {
            let part = Partition::sqrt_n(n);
            let m = part.num_blocks();
            let st = pipeline_schedule(&part, m, 1);
            assert!(
                st.peak_concurrency <= 2 * m + 1,
                "n={n}: peak {} > 2M+1",
                st.peak_concurrency
            );
            assert!(st.peak_concurrency >= m / 2, "n={n}: schedule barely parallel");
        }
    }

    #[test]
    fn pipelining_beats_vanilla_accounting() {
        let part = Partition::with_block(196, 14);
        let p = 3;
        let st = pipeline_schedule(&part, p, 1);
        let vanilla = 14 + p as u64 * (14 + 14); // M + p(B + M)
        assert!(st.finish < vanilla, "{} !< {vanilla}", st.finish);
    }

    #[test]
    fn evals_per_step_scales_times() {
        let part = Partition::with_block(25, 5);
        let a = pipeline_schedule(&part, 2, 1);
        let b = pipeline_schedule(&part, 2, 2);
        assert_eq!(b.finish, 2 * a.finish);
    }

    #[test]
    fn init_only_schedule() {
        let part = Partition::with_block(25, 5);
        let st = pipeline_schedule(&part, 0, 1);
        assert_eq!(st.finish, 5);
        assert_eq!(st.tasks.len(), 5);
    }
}
