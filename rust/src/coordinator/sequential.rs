//! The sequential baseline: `N` fine steps, one after another (paper
//! Eq. 3). This is the exact trajectory SRDS converges to (Prop. 1).

use super::{Conditioning, RunStats};
use crate::buf::BufPool;
use crate::schedule::Grid;
use crate::solvers::{StepBackend, StepRequest};
use std::time::Instant;

/// The baseline chain's accounting, shared by the direct run below and
/// the engine-resident [`crate::exec::task`] chain task: an `n`-step
/// chain is `n` serial evals however it executes. Wall-clock, batch
/// occupancy and pool counters are filled in by the caller.
pub(crate) fn chain_stats(n: usize, epc: u64) -> RunStats {
    RunStats {
        iters: 0,
        converged: true,
        eff_serial_evals: n as u64 * epc,
        eff_serial_evals_pipelined: n as u64 * epc,
        total_evals: n as u64 * epc,
        peak_states: 1,
        ..RunStats::default()
    }
}

/// Run the `n`-step sequential solve from `x0` (the prior sample).
/// Returns the final sample and its accounting.
///
/// Allocation-free after setup: the solve ping-pongs between two pooled
/// state buffers via [`StepBackend::step_into`] (a step may not write
/// over its own input), and the single-sample mask is passed straight
/// through — no per-step tiling.
pub fn sequential(
    backend: &dyn StepBackend,
    x0: &[f32],
    n: usize,
    cond: &Conditioning,
    seed: u64,
) -> (Vec<f32>, RunStats) {
    let t0 = Instant::now();
    let grid = Grid::new(n);
    let pool = BufPool::new();
    let mut x = pool.take(x0);
    let mut next = pool.get(x0.len());
    for i in 0..n {
        let req = StepRequest {
            x: &x,
            s_from: &[grid.s(i)],
            s_to: &[grid.s(i + 1)],
            mask: cond.mask_slice(),
            guidance: cond.guidance,
            seeds: &[seed],
        };
        backend.step_into(&req, next.as_mut_slice());
        std::mem::swap(&mut x, &mut next);
    }
    let epc = backend.evals_per_step() as u64;
    let ps = pool.stats();
    let mut stats = chain_stats(n, epc);
    stats.wall = t0.elapsed();
    stats.pool_hits = ps.hits;
    stats.pool_misses = ps.misses;
    (x.into_vec(), stats)
}

/// Sequential solve that also returns every intermediate block-boundary
/// state (used by the Prop. 1 exactness tests and the toy example).
pub fn sequential_trajectory(
    backend: &dyn StepBackend,
    x0: &[f32],
    n: usize,
    cond: &Conditioning,
    seed: u64,
) -> Vec<Vec<f32>> {
    let grid = Grid::new(n);
    let mut out = Vec::with_capacity(n + 1);
    out.push(x0.to_vec());
    let mut next = vec![0.0f32; x0.len()];
    for i in 0..n {
        let req = StepRequest {
            x: out.last().unwrap(),
            s_from: &[grid.s(i)],
            s_to: &[grid.s(i + 1)],
            mask: cond.mask_slice(),
            guidance: cond.guidance,
            seeds: &[seed],
        };
        backend.step_into(&req, &mut next);
        out.push(next.clone());
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::make_gmm;
    use crate::model::GmmEps;
    use crate::solvers::{NativeBackend, Solver};
    use std::sync::Arc;

    #[test]
    fn accounting_counts_every_step() {
        let be = NativeBackend::new(Arc::new(GmmEps::new(make_gmm("toy2d"))), Solver::Heun);
        let x0 = super::super::prior_sample(2, 1);
        let (_, st) = sequential(&be, &x0, 10, &Conditioning::none(), 1);
        assert_eq!(st.total_evals, 20); // heun = 2 evals/step
        assert_eq!(st.eff_serial_evals, 20);
    }

    #[test]
    fn trajectory_ends_at_sample() {
        let be = NativeBackend::new(Arc::new(GmmEps::new(make_gmm("toy2d"))), Solver::Ddim);
        let x0 = super::super::prior_sample(2, 7);
        let (x, _) = sequential(&be, &x0, 16, &Conditioning::none(), 7);
        let traj = sequential_trajectory(&be, &x0, 16, &Conditioning::none(), 7);
        assert_eq!(traj.len(), 17);
        assert_eq!(traj[16], x);
        assert_eq!(traj[0], x0);
    }

    #[test]
    fn denoised_sample_is_near_the_mixture() {
        // After a full solve the sample should sit close to some component.
        let gmm = make_gmm("toy2d");
        let be = NativeBackend::new(Arc::new(GmmEps::new(gmm.clone())), Solver::Ddim);
        let x0 = super::super::prior_sample(2, 3);
        let (x, _) = sequential(&be, &x0, 200, &Conditioning::none(), 3);
        let min_d = (0..gmm.k())
            .map(|k| {
                let m = gmm.mean_of(k);
                x.iter().zip(m).map(|(a, b)| (a - b) * (a - b)).sum::<f32>().sqrt()
            })
            .fold(f32::MAX, f32::min);
        // within ~3 sigma of the nearest component
        assert!(min_d < 3.0 * 0.6, "sample too far from mixture: {min_d}");
    }
}
