//! Per-run accounting shared by every sampler — the quantities the
//! paper's tables report.

use std::time::Duration;

/// One refinement iteration's bookkeeping.
#[derive(Debug, Clone)]
pub struct IterStat {
    /// Iteration index (1-based, matching Alg. 1's `p`).
    pub iter: usize,
    /// Convergence-norm distance of the final sample to the previous
    /// iterate (the Alg. 1 line-13 quantity).
    pub residual: f32,
    /// Model evaluations spent this iteration.
    pub evals: u64,
}

/// Aggregate accounting for one sampling run.
///
/// *Effective serial evals* counts all model evaluations performed
/// simultaneously in parallel as one evaluation (paper Table 1 caption;
/// called "Parallel Iters" in ParaDiGMS and "Steps" in ParaTAA).
#[derive(Debug, Clone, Default)]
pub struct RunStats {
    /// Refinement iterations executed (0 for the sequential baseline).
    pub iters: usize,
    /// Whether the tolerance test triggered (vs hitting the cap).
    pub converged: bool,
    /// Whether the anytime eval budget
    /// ([`crate::coordinator::SamplerSpec::deadline_evals`]) fired: the
    /// run was truncated to its best completed Parareal iterate instead
    /// of refining to tolerance. Always reported together with an honest
    /// `converged: false` and the achieved residual in
    /// [`RunStats::per_iter`] — a deadline-degraded sample is a valid
    /// early iterate (paper §4), never a silently-worse one.
    pub deadline_hit: bool,
    /// Whether a per-request wall-clock timeout
    /// ([`crate::coordinator::SamplerSpec::timeout_ms`]) fired: the
    /// dispatcher finalized the run from its newest completed Parareal
    /// iterate instead of letting it refine to tolerance. Like
    /// [`RunStats::deadline_hit`], set only when the timeout actually
    /// truncated work (`iters < max_iters` at expiry) and always paired
    /// with an honest `converged: false`.
    pub timed_out: bool,
    /// Effective serial evals under the *vanilla* schedule: the coarse
    /// init sweep, then per iteration max-block fine steps + the
    /// sequential coarse sweep.
    pub eff_serial_evals: u64,
    /// Effective serial evals under the *pipelined* schedule of Fig. 4
    /// (Prop. 2 analysis): iteration `p`'s fine solves start as soon as
    /// their input block state exists.
    pub eff_serial_evals_pipelined: u64,
    /// Total model evaluations (the parallel-compute cost the paper's
    /// Limitations section discusses).
    pub total_evals: u64,
    /// Wall-clock time of the run (measured executor only; zero for
    /// pure accounting runs).
    pub wall: Duration,
    /// Peak number of `dim`-sized trajectory states held simultaneously —
    /// the paper's §3.6 memory comparison (O(√N) for SRDS vs O(window)
    /// for ParaDiGMS vs O(N·history) for ParaTAA; 1 for sequential).
    pub peak_states: usize,
    /// Mean rows per multi-tenant-engine batch that this run's step rows
    /// rode in (`crate::exec::engine`); > 1.0 means the run's steps were
    /// fused with other step work (its own or co-tenant requests'). 0
    /// when the run did not execute on the engine. Every engine-served
    /// request — any registered sampler, each running as its own
    /// `crate::exec::task::SamplerTask` — meters this per request.
    pub batch_occupancy: f64,
    /// Step rows this run contributed to the engine (0 off-engine).
    pub engine_rows: u64,
    /// State-buffer pool requests served from the free list
    /// ([`crate::buf::BufPool`]). For coordinator runs this is the
    /// run-local pool; for engine-resident requests it is a snapshot of
    /// the engine's shared pool at completion — either way, steady-state
    /// zero allocation means `pool_misses` stops growing while
    /// `pool_hits` keeps climbing.
    pub pool_hits: u64,
    /// Pool requests that had to allocate a fresh buffer (see
    /// [`RunStats::pool_hits`]).
    pub pool_misses: u64,
    /// Per-iteration details.
    pub per_iter: Vec<IterStat>,
}

impl RunStats {
    /// Speedup in effective serial evals vs an `n`-step sequential solve
    /// with the same solver (evals/step included in both sides).
    pub fn eval_speedup_vs_serial(&self, n: usize, evals_per_step: usize) -> f64 {
        (n * evals_per_step) as f64 / self.eff_serial_evals_pipelined.max(1) as f64
    }
}

/// Streaming mean/variance (Welford) used by metrics and the benches.
#[derive(Debug, Clone, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn count(&self) -> u64 {
        self.n
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_direct() {
        let xs = [1.0, 2.0, 3.0, 4.0, 10.0];
        let mut w = Welford::default();
        for &x in &xs {
            w.push(x);
        }
        assert!((w.mean() - 4.0).abs() < 1e-12);
        let direct_var = xs.iter().map(|x| (x - 4.0) * (x - 4.0)).sum::<f64>() / 4.0;
        assert!((w.var() - direct_var).abs() < 1e-12);
    }

    #[test]
    fn speedup_accounting() {
        let st = RunStats { eff_serial_evals_pipelined: 9, ..Default::default() };
        assert!((st.eval_speedup_vs_serial(25, 1) - 25.0 / 9.0).abs() < 1e-12);
    }
}
