//! Diffusion ODE solvers (the `F`/`G` maps Parareal composes).
//!
//! A *solver* is a deterministic map `F(x, s_from, s_to)` propagating the
//! state (paper §2.1). SRDS instantiates the fine solver as `block`
//! consecutive steps on the fine grid and the coarse solver as a single
//! step across a block (paper §3.2).
//!
//! Two interchangeable execution paths implement [`StepBackend`]:
//! [`native::NativeBackend`] (pure rust, mirrors `python/compile/model.py`
//! to f32 tolerance) and [`crate::runtime::PjrtBackend`] (AOT-compiled
//! HLO artifacts via PJRT). Golden tests pin them together.

mod native;

pub use native::NativeBackend;

use crate::data::rng::{noise_key, SplitMix64};
use crate::schedule;

/// Solver families (paper §2.1 + App. C Table 5).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Solver {
    /// DDIM (η = 0) — the paper's default.
    Ddim,
    /// DDIM(η = 1) ancestral sampling with deterministic per-position noise.
    Ddpm,
    /// Explicit Euler on the probability-flow ODE.
    Euler,
    /// Heun's 2nd-order method (Karras et al.) — 2 evals/step.
    Heun,
    /// DPM-Solver-2 midpoint (Lu et al.) — 2 evals/step.
    Dpm2,
}

impl Solver {
    pub const ALL: [Solver; 5] = [Solver::Ddim, Solver::Ddpm, Solver::Euler, Solver::Heun, Solver::Dpm2];

    /// Model evaluations per step — the unit every latency table counts.
    pub fn evals_per_step(self) -> usize {
        match self {
            Solver::Ddim | Solver::Ddpm | Solver::Euler => 1,
            Solver::Heun | Solver::Dpm2 => 2,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Solver::Ddim => "ddim",
            Solver::Ddpm => "ddpm",
            Solver::Euler => "euler",
            Solver::Heun => "heun",
            Solver::Dpm2 => "dpm2",
        }
    }

    pub fn parse(s: &str) -> Option<Solver> {
        Solver::ALL.into_iter().find(|v| v.name() == s)
    }

    /// Whether the step consumes an exogenous noise vector.
    pub fn stochastic(self) -> bool {
        matches!(self, Solver::Ddpm)
    }
}

/// One batched step request: row `i` propagates from `s_from[i]` to
/// `s_to[i]`. Rows are independent — this is exactly the batched-inference
/// opportunity of paper §3.4 (fine solves of different blocks, or of
/// different requests, share one model evaluation).
#[derive(Debug, Clone, Copy)]
pub struct StepRequest<'a> {
    /// Flat `(b, dim)` states.
    pub x: &'a [f32],
    pub s_from: &'a [f32],
    pub s_to: &'a [f32],
    /// Component mask `(b, k)` for guided models.
    pub mask: Option<&'a [f32]>,
    /// Classifier-free guidance weight (ignored when `mask` is `None`).
    pub guidance: f32,
    /// Per-row noise seeds (DDPM); noise is a pure function of
    /// `(seed, s_from)` so the step map stays deterministic.
    pub seeds: &'a [u64],
}

impl<'a> StepRequest<'a> {
    pub fn rows(&self) -> usize {
        self.s_from.len()
    }
}

/// Where a solver step executes. Object-safe; PJRT-backed impls are
/// thread-bound (the `xla` crate's client is `Rc`-based), so backends are
/// created per worker thread via [`BackendFactory`].
///
/// The required method is the *write-into* form [`StepBackend::step_into`]:
/// the caller owns the output buffer (typically a pooled
/// [`crate::buf::StateBuf`] or a [`crate::buf::BatchStage`]'s persistent
/// output), so steady-state step loops allocate nothing. `out` must not
/// alias `req.x` (guaranteed by `&mut` — ping-pong two buffers when
/// feeding a step its own output). Implementations may keep internal
/// scratch (they are `!Sync`, one instance per thread).
pub trait StepBackend {
    fn dim(&self) -> usize;
    fn solver(&self) -> Solver;
    /// Execute one batched solver step, writing the flat `(b, dim)`
    /// result into `out` (whose length must be exactly `b * dim`).
    fn step_into(&self, req: &StepRequest, out: &mut [f32]);
    /// Allocating convenience wrapper over [`StepBackend::step_into`]
    /// (tests, one-off callers — not the hot path).
    fn step(&self, req: &StepRequest) -> Vec<f32> {
        let mut out = vec![0.0f32; req.rows() * self.dim()];
        self.step_into(req, &mut out);
        out
    }
    fn evals_per_step(&self) -> usize {
        self.solver().evals_per_step()
    }
}

/// Creates per-thread [`StepBackend`] instances for the measured executor.
pub trait BackendFactory: Send + Sync {
    fn create(&self) -> Box<dyn StepBackend>;
    fn dim(&self) -> usize;
    fn solver(&self) -> Solver;
}

/// Deterministic DDPM noise for one row: a pure function of
/// `(seed, s_from)` shared by the native backend and the PJRT wrapper
/// (which feeds it to the artifact's `noise` input).
pub fn ddpm_noise(seed: u64, s_from: f32, dim: usize, out: &mut [f32]) {
    let key = noise_key(seed, s_from.to_bits(), 0);
    SplitMix64::new(key).fill_normals(&mut out[..dim]);
}

/// Shared per-row DDIM coefficients: `x' = c1·x + c2·ε`.
#[inline]
pub fn ddim_coeffs(s_from: f32, s_to: f32) -> (f32, f32) {
    let (sab_f, sab_t) = (schedule::sqrt_ab(s_from), schedule::sqrt_ab(s_to));
    let (sig_f, sig_t) = (schedule::sigma(s_from), schedule::sigma(s_to));
    let c1 = sab_t / sab_f;
    (c1, sig_t - c1 * sig_f)
}

/// Shared per-row DDPM(η=1) coefficients: `x' = c1·x + c2·ε + c3·ξ`.
#[inline]
pub fn ddpm_coeffs(s_from: f32, s_to: f32) -> (f32, f32, f32) {
    let (ab_f, ab_t) = (schedule::alpha_bar(s_from), schedule::alpha_bar(s_to));
    let (sab_f, sab_t) = (ab_f.sqrt(), ab_t.sqrt());
    let (sig_f, sig_t) = (schedule::sigma(s_from), schedule::sigma(s_to));
    let std = ((sig_t / sig_f) * (1.0 - ab_f / ab_t).max(0.0).sqrt()).min(sig_t);
    let dir = (sig_t * sig_t - std * std).max(0.0).sqrt();
    let c1 = sab_t / sab_f;
    (c1, dir - c1 * sig_f, std)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eval_counts() {
        assert_eq!(Solver::Ddim.evals_per_step(), 1);
        assert_eq!(Solver::Heun.evals_per_step(), 2);
        assert_eq!(Solver::Dpm2.evals_per_step(), 2);
    }

    #[test]
    fn parse_roundtrip() {
        for s in Solver::ALL {
            assert_eq!(Solver::parse(s.name()), Some(s));
        }
        assert_eq!(Solver::parse("nope"), None);
    }

    #[test]
    fn ddim_identity_when_times_equal() {
        let (c1, c2) = ddim_coeffs(0.3, 0.3);
        assert!((c1 - 1.0).abs() < 1e-6);
        assert!(c2.abs() < 1e-6);
    }

    #[test]
    fn ddpm_noise_is_deterministic() {
        let mut a = vec![0.0; 16];
        let mut b = vec![0.0; 16];
        ddpm_noise(7, 0.25, 16, &mut a);
        ddpm_noise(7, 0.25, 16, &mut b);
        assert_eq!(a, b);
        ddpm_noise(8, 0.25, 16, &mut b);
        assert_ne!(a, b);
    }

    #[test]
    fn ddpm_variance_is_bounded() {
        for i in 0..20 {
            let s = i as f32 / 20.0;
            let t = s + 0.05;
            let (_, _, c3) = ddpm_coeffs(s, t);
            assert!(c3 >= 0.0 && c3 <= crate::schedule::sigma(t) + 1e-6);
        }
    }
}
