//! Pure-rust solver steps over an [`EpsModel`] — mirrors the JAX step
//! functions in `python/compile/model.py` operation-for-operation (f32),
//! so native solves agree with the AOT HLO artifacts to fp tolerance
//! (pinned by `rust/tests/golden.rs`).
//!
//! The arithmetic itself runs on the lane-tiled primitives in
//! [`crate::kernels`]: each solver computes all per-row schedule
//! coefficients once into small per-row scratch lanes, then applies the
//! update as a single contiguous kernel pass per row. Rows never
//! interact, and every kernel's per-row op order is fixed, so each
//! row's output is bit-identical regardless of batch shape (pinned by
//! `batched_mixed_rows_equal_solo_rows` below and `tests/batch_shape.rs`).

use super::{ddim_coeffs, ddpm_coeffs, ddpm_noise, Solver, StepBackend, StepRequest};
use crate::buf::sized;
use crate::kernels;
use crate::model::EpsModel;
use crate::schedule;
use std::cell::RefCell;
use std::sync::Arc;

/// Per-backend scratch, reused across [`StepBackend::step_into`] calls so
/// the 2-eval solvers (Heun, DPM-2), DDPM's noise row, and the per-row
/// coefficient lanes never allocate on the hot path. Sized lazily to the
/// largest batch seen.
#[derive(Default)]
struct Scratch {
    /// Full (b, d) model-eval rows: first slope / midpoint eps, midpoint
    /// state / Heun predictor, and DDPM's per-row noise (d only).
    a: Vec<f32>,
    b: Vec<f32>,
    s: Vec<f32>,
    /// Per-row schedule-coefficient lanes (length b), filled once per
    /// step and then applied in one lane-tiled kernel pass per row.
    c1: Vec<f32>,
    c2: Vec<f32>,
    c3: Vec<f32>,
    c4: Vec<f32>,
}

/// Iterate parallel row slices of an input and an output (b, d) matrix.
// lint: hot-path
fn rows2<'a>(
    x: &'a [f32],
    out: &'a mut [f32],
    d: usize,
) -> impl Iterator<Item = (&'a [f32], &'a mut [f32])> + 'a {
    x.chunks_exact(d).zip(out.chunks_exact_mut(d))
}

/// Native backend: batched eps through the model, per-row schedule
/// coefficients, fused lane-tiled update.
///
/// Every solver path makes **one batched model call per eval** (two for
/// the 2-eval solvers) followed by a single kernel pass per row applying
/// the precomputed coefficients — rows never interact. The multi-tenant
/// engine (`crate::exec::engine`) relies on exactly this: it fuses step
/// rows from *different requests* into one `StepRequest` (and splits
/// large batches into row chunks across workers), and per-request
/// outputs must be bit-identical to a solo run (pinned below by
/// `batched_mixed_rows_equal_solo_rows` and by the engine's equivalence
/// tests).
///
/// The scratch `RefCell` makes the backend `!Sync` — one instance per
/// thread, which is already the [`super::BackendFactory`] contract.
pub struct NativeBackend {
    model: Arc<dyn EpsModel>,
    solver: Solver,
    scratch: RefCell<Scratch>,
}

impl NativeBackend {
    pub fn new(model: Arc<dyn EpsModel>, solver: Solver) -> Self {
        NativeBackend { model, solver, scratch: RefCell::new(Scratch::default()) }
    }

    pub fn model(&self) -> &Arc<dyn EpsModel> {
        &self.model
    }

    fn eps(&self, x: &[f32], s: &[f32], req: &StepRequest, out: &mut [f32]) {
        match req.mask {
            Some(mask) => self.model.eps_guided(x, s, mask, req.guidance, out),
            None => self.model.eps(x, s, None, out),
        }
    }

    /// Probability-flow slope `dx/ds = 0.5 β(1-s) (x − ε̂/σ(s))` per row.
    // lint: hot-path
    fn pf_slope(&self, x: &[f32], s: &[f32], req: &StepRequest, out: &mut [f32]) {
        let d = self.model.dim();
        self.eps(x, s, req, out);
        for (i, (xr, o)) in rows2(x, out, d).enumerate() {
            let c = 0.5 * schedule::beta(1.0 - s[i]);
            kernels::pf_transform(c, schedule::sigma(s[i]), xr, o);
        }
    }
}

impl StepBackend for NativeBackend {
    fn dim(&self) -> usize {
        self.model.dim()
    }

    fn solver(&self) -> Solver {
        self.solver
    }

    // lint: hot-path
    fn step_into(&self, req: &StepRequest, out: &mut [f32]) {
        let b = req.rows();
        let d = self.model.dim();
        debug_assert_eq!(out.len(), b * d, "step_into output must be exactly (b, dim)");
        let mut sc = self.scratch.borrow_mut();
        match self.solver {
            Solver::Ddim => {
                self.eps(req.x, req.s_from, req, out);
                let Scratch { c1, c2, .. } = &mut *sc;
                sized(c1, b);
                sized(c2, b);
                for i in 0..b {
                    (c1[i], c2[i]) = ddim_coeffs(req.s_from[i], req.s_to[i]);
                }
                for (i, (x, o)) in rows2(req.x, out, d).enumerate() {
                    kernels::axpby(c1[i], x, c2[i], o);
                }
            }
            Solver::Ddpm => {
                self.eps(req.x, req.s_from, req, out);
                let Scratch { a: xi, c1, c2, c3, .. } = &mut *sc;
                sized(xi, d);
                sized(c1, b);
                sized(c2, b);
                sized(c3, b);
                for i in 0..b {
                    (c1[i], c2[i], c3[i]) = ddpm_coeffs(req.s_from[i], req.s_to[i]);
                }
                for (i, (x, o)) in rows2(req.x, out, d).enumerate() {
                    ddpm_noise(req.seeds[i], req.s_from[i], d, xi);
                    kernels::axpbypcz(c1[i], x, c2[i], c3[i], xi, o);
                }
            }
            Solver::Euler => {
                self.pf_slope(req.x, req.s_from, req, out);
                let Scratch { c1, .. } = &mut *sc;
                sized(c1, b);
                for i in 0..b {
                    c1[i] = req.s_to[i] - req.s_from[i];
                }
                for (i, (x, o)) in rows2(req.x, out, d).enumerate() {
                    kernels::axpby(1.0, x, c1[i], o);
                }
            }
            Solver::Heun => {
                let Scratch { a: d1, b: xe, c1, .. } = &mut *sc;
                sized(d1, b * d);
                sized(xe, b * d);
                sized(c1, b);
                for i in 0..b {
                    c1[i] = req.s_to[i] - req.s_from[i];
                }
                self.pf_slope(req.x, req.s_from, req, d1);
                for (i, (x, xe_r)) in rows2(req.x, xe, d).enumerate() {
                    kernels::add_scaled(x, c1[i], &d1[i * d..(i + 1) * d], xe_r);
                }
                self.pf_slope(xe, req.s_to, req, out);
                for (i, (x, o)) in rows2(req.x, out, d).enumerate() {
                    kernels::avg_step(x, 0.5 * c1[i], &d1[i * d..(i + 1) * d], o);
                }
            }
            Solver::Dpm2 => {
                // Exponential-integrator midpoint in half-log-SNR space.
                // All per-row schedule coefficients (lam, h, the midpoint
                // and full-step x/eps weights) are computed once here; the
                // second pass used to recompute lam and h per row
                // (`dpm2_coefficient_hoist_is_bitwise_neutral` pins the
                // hoist as a pure refactor).
                let Scratch { a: e1, b: u, s: s_mid, c1, c2, c3, c4 } = &mut *sc;
                sized(e1, b * d);
                sized(u, b * d);
                sized(s_mid, b);
                sized(c1, b);
                sized(c2, b);
                sized(c3, b);
                sized(c4, b);
                for i in 0..b {
                    let lam_f = schedule::lam(req.s_from[i]);
                    let h = schedule::lam(req.s_to[i]) - lam_f;
                    s_mid[i] = schedule::s_of_lam(lam_f + 0.5 * h);
                    let sab_f = schedule::sqrt_ab(req.s_from[i]);
                    c1[i] = schedule::sqrt_ab(s_mid[i]) / sab_f;
                    c2[i] = -schedule::sigma(s_mid[i]) * (0.5 * h).exp_m1();
                    c3[i] = schedule::sqrt_ab(req.s_to[i]) / sab_f;
                    c4[i] = -schedule::sigma(req.s_to[i]) * h.exp_m1();
                }
                self.eps(req.x, req.s_from, req, e1);
                for (i, (x, u_r)) in rows2(req.x, u, d).enumerate() {
                    kernels::lincomb(c1[i], x, c2[i], &e1[i * d..(i + 1) * d], u_r);
                }
                self.eps(u, s_mid, req, out);
                for (i, (x, o)) in rows2(req.x, out, d).enumerate() {
                    kernels::axpby(c3[i], x, c4[i], o);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::make_gmm;
    use crate::model::{GmmEps, ZeroModel};
    use std::sync::Arc;

    fn req<'a>(
        x: &'a [f32],
        s_from: &'a [f32],
        s_to: &'a [f32],
        seeds: &'a [u64],
    ) -> StepRequest<'a> {
        StepRequest { x, s_from, s_to, mask: None, guidance: 0.0, seeds }
    }

    #[test]
    fn ddim_zero_model_closed_form() {
        // With eps = 0 the DDIM update x' = c1·x + c2·ε̂ collapses to
        // x' = (sab_t/sab_f)·x: the eps coefficient c2 = sig_t − c1·sig_f
        // multiplies ε̂ = 0, leaving only the signal rescale.
        let be = NativeBackend::new(Arc::new(ZeroModel { dim: 4 }), Solver::Ddim);
        let x = [1.0f32, -2.0, 0.5, 3.0];
        let out = be.step(&req(&x, &[0.2], &[0.6], &[0]));
        let c1 = schedule::sqrt_ab(0.6) / schedule::sqrt_ab(0.2);
        for j in 0..4 {
            assert!((out[j] - c1 * x[j]).abs() < 1e-6);
        }
    }

    #[test]
    fn all_solvers_approach_same_solution_as_steps_increase() {
        // Integrating the full trajectory with many steps, every
        // deterministic solver should land near the same x(1).
        let gmm = make_gmm("cifar");
        let model: Arc<dyn crate::model::EpsModel> = Arc::new(GmmEps::new(gmm));
        let d = 64;
        let mut rng = crate::data::rng::SplitMix64::new(77);
        let x0 = rng.normals_f32(d);
        let n = 400;
        let mut finals = vec![];
        for solver in [Solver::Ddim, Solver::Euler, Solver::Heun, Solver::Dpm2] {
            let be = NativeBackend::new(model.clone(), solver);
            let mut x = x0.clone();
            for i in 0..n {
                let s0 = i as f32 / n as f32;
                let s1 = (i + 1) as f32 / n as f32;
                x = be.step(&req(&x, &[s0], &[s1], &[0]));
            }
            finals.push(x);
        }
        for other in &finals[1..] {
            let rel: f32 = finals[0]
                .iter()
                .zip(other)
                .map(|(a, b)| (a - b).abs())
                .sum::<f32>()
                / d as f32;
            assert!(rel < 0.08, "solver disagreement {rel}");
        }
    }

    #[test]
    fn ddpm_step_is_deterministic_given_seed() {
        let gmm = make_gmm("church");
        let be = NativeBackend::new(Arc::new(GmmEps::new(gmm)), Solver::Ddpm);
        let mut rng = crate::data::rng::SplitMix64::new(1);
        let x = rng.normals_f32(64);
        let a = be.step(&req(&x, &[0.3], &[0.4], &[42]));
        let b = be.step(&req(&x, &[0.3], &[0.4], &[42]));
        assert_eq!(a, b);
        let c = be.step(&req(&x, &[0.3], &[0.4], &[43]));
        assert_ne!(a, c);
    }

    #[test]
    fn batched_equals_rowwise_all_solvers() {
        let gmm = make_gmm("bedroom");
        let model: Arc<dyn crate::model::EpsModel> = Arc::new(GmmEps::new(gmm));
        let d = 64;
        let b = 4;
        let mut rng = crate::data::rng::SplitMix64::new(2);
        let x = rng.normals_f32(b * d);
        let s_from: Vec<f32> = (0..b).map(|i| 0.1 + 0.2 * i as f32).collect();
        let s_to: Vec<f32> = s_from.iter().map(|s| s + 0.1).collect();
        let seeds: Vec<u64> = (0..b as u64).collect();
        for solver in Solver::ALL {
            let be = NativeBackend::new(model.clone(), solver);
            let full = be.step(&req(&x, &s_from, &s_to, &seeds));
            for i in 0..b {
                let row = be.step(&req(
                    &x[i * d..(i + 1) * d],
                    &s_from[i..=i],
                    &s_to[i..=i],
                    &seeds[i..=i],
                ));
                for j in 0..d {
                    assert!(
                        (full[i * d + j] - row[j]).abs() < 1e-6,
                        "{} row {i} dim {j}",
                        solver.name()
                    );
                }
            }
        }
    }

    #[test]
    fn batched_mixed_rows_equal_solo_rows() {
        // The engine's fusion contract, bit-level: a batch mixing rows
        // from unrelated "requests" (different states, times, seeds, in
        // arbitrary order) produces each row's solo result exactly.
        let gmm = make_gmm("church");
        let model: Arc<dyn crate::model::EpsModel> = Arc::new(GmmEps::new(gmm));
        let d = 64;
        let mut rng = crate::data::rng::SplitMix64::new(9);
        for solver in [Solver::Ddim, Solver::Ddpm] {
            let be = NativeBackend::new(model.clone(), solver);
            // Three unrelated rows at very different schedule positions.
            let x = rng.normals_f32(3 * d);
            let s_from = [0.05f32, 0.8, 0.41];
            let s_to = [0.1f32, 0.85, 0.47];
            let seeds = [7u64, 900, 31];
            let fused = be.step(&req(&x, &s_from, &s_to, &seeds));
            for i in 0..3 {
                let solo = be.step(&req(
                    &x[i * d..(i + 1) * d],
                    &s_from[i..=i],
                    &s_to[i..=i],
                    &seeds[i..=i],
                ));
                assert_eq!(
                    &fused[i * d..(i + 1) * d],
                    &solo[..],
                    "{} row {i} not bit-identical in a mixed batch",
                    solver.name()
                );
            }
        }
    }

    #[test]
    fn dpm2_coefficient_hoist_is_bitwise_neutral() {
        // Pins the coefficient-scratch rework as a pure refactor: the
        // second DPM2 pass used to recompute schedule::lam / h per row.
        // Re-derive the step with the historical two-pass formulas
        // (lam and h recomputed in each pass, scalar loops) and require
        // bit equality with step_into.
        let gmm = make_gmm("church");
        let model: Arc<dyn crate::model::EpsModel> = Arc::new(GmmEps::new(gmm));
        let d = 64;
        let b = 4;
        let mut rng = crate::data::rng::SplitMix64::new(11);
        let x = rng.normals_f32(b * d);
        let s_from: Vec<f32> = (0..b).map(|i| 0.07 + 0.21 * i as f32).collect();
        let s_to: Vec<f32> = s_from.iter().map(|s| s + 0.09).collect();
        let seeds = vec![0u64; b];
        let be = NativeBackend::new(model.clone(), Solver::Dpm2);
        let got = be.step(&req(&x, &s_from, &s_to, &seeds));

        let mut e1 = vec![0.0f32; b * d];
        model.eps(&x, &s_from, None, &mut e1);
        let mut u = vec![0.0f32; b * d];
        let mut s_mid = vec![0.0f32; b];
        for i in 0..b {
            let lam_f = schedule::lam(s_from[i]);
            let lam_t = schedule::lam(s_to[i]);
            let h = lam_t - lam_f;
            s_mid[i] = schedule::s_of_lam(lam_f + 0.5 * h);
            let c1 = schedule::sqrt_ab(s_mid[i]) / schedule::sqrt_ab(s_from[i]);
            let c2 = -schedule::sigma(s_mid[i]) * (0.5 * h).exp_m1();
            for j in 0..d {
                u[i * d + j] = c1 * x[i * d + j] + c2 * e1[i * d + j];
            }
        }
        let mut want = vec![0.0f32; b * d];
        model.eps(&u, &s_mid, None, &mut want);
        for i in 0..b {
            let lam_f = schedule::lam(s_from[i]);
            let h = schedule::lam(s_to[i]) - lam_f;
            let c1 = schedule::sqrt_ab(s_to[i]) / schedule::sqrt_ab(s_from[i]);
            let c2 = -schedule::sigma(s_to[i]) * h.exp_m1();
            for j in 0..d {
                want[i * d + j] = c1 * x[i * d + j] + c2 * want[i * d + j];
            }
        }
        assert_eq!(got, want);
    }

    // Scratch-reuse bitwise stability across varying batch shapes is
    // pinned in rust/tests/golden.rs (`step_into_scratch_reuse_*`), for
    // both backends — no duplicate unit-level copy here.

    #[test]
    fn heun_more_accurate_than_euler() {
        // On a coarse grid, Heun should land closer to a fine reference.
        let gmm = make_gmm("imagenet64");
        let model: Arc<dyn crate::model::EpsModel> = Arc::new(GmmEps::new(gmm));
        let d = 64;
        let mut rng = crate::data::rng::SplitMix64::new(5);
        let x0 = rng.normals_f32(d);
        let solve = |solver: Solver, n: usize| {
            let be = NativeBackend::new(model.clone(), solver);
            let mut x = x0.clone();
            for i in 0..n {
                x = be.step(&req(
                    &x,
                    &[i as f32 / n as f32],
                    &[(i + 1) as f32 / n as f32],
                    &[0],
                ));
            }
            x
        };
        let reference = solve(Solver::Heun, 512);
        let l1 = |a: &[f32], b: &[f32]| -> f32 {
            a.iter().zip(b).map(|(x, y)| (x - y).abs()).sum::<f32>() / d as f32
        };
        let err_euler = l1(&solve(Solver::Euler, 24), &reference);
        let err_heun = l1(&solve(Solver::Heun, 24), &reference);
        assert!(
            err_heun < err_euler,
            "heun {err_heun} should beat euler {err_euler}"
        );
    }
}
