//! Property-based tests of the paper's propositions (randomized over
//! many cases via the in-tree splitmix64 — the vendored crate set has no
//! proptest, so generation is explicit and fully deterministic).
//!
//! * Prop. 1 — SRDS equals the sequential solve after ≤ M refinements,
//!   bitwise, for random (N, block, model).
//! * Prop. 2 — pipelined makespan ≤ N·epc with enough devices.
//! * Prop. 3 — concurrency stays O(√N); bounded devices are respected.
//! * Prop. 4 — per-iteration cost `⌈N/B⌉ + B` is minimized at B ≈ √N.

use srds::coordinator::pipeline::pipeline_schedule;
use srds::coordinator::{prior_sample, sequential, Conditioning, SamplerSpec};
use srds::data::rng::SplitMix64;
use srds::exec::{simulate_srds, NativeFactory, WorkerPool};
use srds::json;
use srds::model::{AffineModel, EpsModel};
use srds::schedule::Partition;
use srds::solvers::{NativeBackend, Solver};
use std::sync::Arc;

const CASES: usize = 40;

#[test]
fn prop1_srds_equals_sequential_after_m_iterations() {
    let mut rng = SplitMix64::new(0xA11CE);
    for case in 0..CASES {
        let n = 2 + (rng.next_u64() % 60) as usize;
        let dim = 1 + (rng.next_u64() % 6) as usize;
        let a = (rng.next_f64() as f32) * 1.2 - 0.6;
        let c = (rng.next_f64() as f32) * 0.8;
        let block = 1 + (rng.next_u64() as usize % n);
        let solver = if rng.next_u64() % 2 == 0 { Solver::Ddim } else { Solver::Euler };
        let be = NativeBackend::new(Arc::new(AffineModel::new(dim, a, c)), solver);
        let seed = rng.next_u64();
        let x0 = prior_sample(dim, seed);
        let (seq, _) = sequential(&be, &x0, n, &Conditioning::none(), seed);
        let part = Partition::with_block(n, block);
        let cfg = SamplerSpec::srds(n)
            .with_block(block)
            .with_tol(0.0)
            .with_max_iters(part.num_blocks())
            .with_seed(seed);
        let res = srds::coordinator::srds(&be, &x0, &cfg);
        assert_eq!(
            res.sample,
            seq,
            "case {case}: n={n} block={block} a={a} solver={}",
            solver.name()
        );
    }
}

#[test]
fn prop1_ddpm_exactness_with_derived_noise() {
    // The stochastic solver is a deterministic map given the seed, so
    // Parareal exactness must hold for it too.
    let mut rng = SplitMix64::new(0xBEEF);
    for _ in 0..12 {
        let n = 4 + (rng.next_u64() % 30) as usize;
        let dim = 2 + (rng.next_u64() % 4) as usize;
        let be = NativeBackend::new(Arc::new(AffineModel::new(dim, 0.3, 0.2)), Solver::Ddpm);
        let seed = rng.next_u64();
        let x0 = prior_sample(dim, seed);
        let (seq, _) = sequential(&be, &x0, n, &Conditioning::none(), seed);
        let part = Partition::sqrt_n(n);
        let cfg = SamplerSpec::srds(n)
            .with_tol(0.0)
            .with_max_iters(part.num_blocks())
            .with_seed(seed);
        let res = srds::coordinator::srds(&be, &x0, &cfg);
        assert_eq!(res.sample, seq, "n={n} dim={dim}");
    }
}

#[test]
fn prop2_pipelined_makespan_never_exceeds_sequential() {
    let mut rng = SplitMix64::new(0xCAFE);
    for _ in 0..CASES {
        let n = 4 + (rng.next_u64() % 400) as usize;
        let epc = 1 + (rng.next_u64() % 2);
        let part = Partition::sqrt_n(n);
        let m = part.num_blocks();
        // Ideal schedule at the Prop. 1 worst case of M refinements.
        let st = pipeline_schedule(&part, m, epc);
        assert!(
            st.finish <= n as u64 * epc,
            "n={n} epc={epc}: {} > {}",
            st.finish,
            n as u64 * epc
        );
        // Bounded-device simulation with ample devices agrees.
        let sim = simulate_srds(&part, m, epc, 2 * m + 2, true);
        assert!(sim.makespan <= n as u64 * epc, "sim n={n}");
    }
}

#[test]
fn prop3_concurrency_bounds() {
    let mut rng = SplitMix64::new(0xD00D);
    for _ in 0..CASES {
        let n = 9 + (rng.next_u64() % 500) as usize;
        let part = Partition::sqrt_n(n);
        let m = part.num_blocks();
        let iters = 1 + (rng.next_u64() as usize % m);
        let ideal = pipeline_schedule(&part, iters, 1);
        assert!(
            ideal.peak_concurrency <= 2 * m + 1,
            "n={n} iters={iters}: peak {}",
            ideal.peak_concurrency
        );
        // A D-device schedule never runs more than D tasks at once.
        let d = 1 + (rng.next_u64() as usize % (m + 2));
        let sim = simulate_srds(&part, iters, 1, d, true);
        assert!(sim.peak_concurrency <= d, "devices {d}: peak {}", sim.peak_concurrency);
    }
}

#[test]
fn prop4_sqrt_block_minimizes_iteration_cost() {
    // cost(B) = ⌈N/B⌉ + B; check B = round(√N) is within +1 of the true
    // optimum for every N up to 2048 (exhaustive, not sampled).
    for n in 2..=2048usize {
        let cost = |b: usize| (n.div_ceil(b) + b) as f64;
        let best_b = (1..=n).min_by(|&a, &b| cost(a).partial_cmp(&cost(b)).unwrap()).unwrap();
        let best = cost(best_b);
        let at_sqrt = cost(((n as f64).sqrt().round() as usize).max(1));
        assert!(
            at_sqrt <= best + 1.0 + 1e-9,
            "n={n}: cost(sqrt)={at_sqrt} best={best} at B={best_b}"
        );
    }
}

#[test]
fn block_size_one_and_n_are_degenerate() {
    // B = N → one block: SRDS is just the fine solve after 1 iteration.
    let dim = 3;
    let be = NativeBackend::new(Arc::new(AffineModel::new(dim, 0.5, 0.1)), Solver::Ddim);
    let x0 = prior_sample(dim, 5);
    let n = 20;
    let (seq, _) = sequential(&be, &x0, n, &Conditioning::none(), 5);
    let cfg = SamplerSpec::srds(n).with_block(n).with_tol(0.0).with_max_iters(1).with_seed(5);
    let res = srds::coordinator::srds(&be, &x0, &cfg);
    assert_eq!(res.sample, seq);
    // B = 1 → coarse == fine: converged after the first refinement.
    let cfg = SamplerSpec::srds(n).with_block(1).with_tol(1e-9).with_seed(5);
    let res = srds::coordinator::srds(&be, &x0, &cfg);
    assert_eq!(res.sample, seq);
    assert_eq!(res.stats.iters, 1);
}

#[test]
fn measured_pipeline_equals_vanilla_for_random_configs() {
    let mut rng = SplitMix64::new(0xF00D);
    let model: Arc<dyn EpsModel> = Arc::new(AffineModel::new(4, 0.4, 0.3));
    let pool = WorkerPool::new(Arc::new(NativeFactory::new(model.clone(), Solver::Ddim)), 3);
    for _ in 0..10 {
        let n = 4 + (rng.next_u64() % 40) as usize;
        let seed = rng.next_u64();
        let x0 = prior_sample(4, seed);
        let cfg = SamplerSpec::srds(n).with_tol(1e-5).with_seed(seed);
        let be = NativeBackend::new(model.clone(), Solver::Ddim);
        let vanilla = srds::coordinator::srds(&be, &x0, &cfg);
        let measured =
            srds::exec::measured_pipelined_srds(&pool, &x0, &cfg);
        assert_eq!(measured.stats.iters, vanilla.stats.iters, "n={n}");
        assert_eq!(measured.sample, vanilla.sample, "n={n}");
    }
}

#[test]
fn json_roundtrip_random_documents() {
    let mut rng = SplitMix64::new(0x15AAC);
    for _ in 0..60 {
        let v = random_json(&mut rng, 0);
        let text = json::to_string(&v);
        let back = json::parse(&text).expect("parse own output");
        assert_eq!(back, v, "doc: {text}");
    }
}

fn random_json(rng: &mut SplitMix64, depth: usize) -> json::Value {
    use json::Value;
    let choice = rng.next_u64() % if depth > 3 { 4 } else { 6 };
    match choice {
        0 => Value::Null,
        1 => Value::Bool(rng.next_u64() % 2 == 0),
        2 => Value::Num((rng.next_f64() * 2000.0 - 1000.0).round() / 8.0),
        3 => {
            let len = rng.next_u64() % 8;
            let s: String = (0..len)
                .map(|_| char::from_u32(0x20 + (rng.next_u64() % 0x50) as u32).unwrap())
                .collect();
            Value::Str(s)
        }
        4 => {
            let len = (rng.next_u64() % 4) as usize;
            Value::Arr((0..len).map(|_| random_json(rng, depth + 1)).collect())
        }
        _ => {
            let len = (rng.next_u64() % 4) as usize;
            let mut m = std::collections::BTreeMap::new();
            for i in 0..len {
                m.insert(format!("k{i}"), random_json(rng, depth + 1));
            }
            Value::Obj(m)
        }
    }
}
