//! Cross-layer integration tests: PJRT-backed sampling end-to-end, the
//! TCP serving loop, and quality preservation (the paper's headline
//! "approximation-free" property measured with the FD metric).
//!
//! PJRT tests self-skip when artifacts are absent.

use srds::coordinator::{prior_sample, sequential, srds as run_srds, Conditioning, SamplerSpec};
use srds::data::make_gmm;
use srds::exec::{measured_pipelined_srds, NativeFactory, WorkerPool};
use srds::metrics::{fd_vs_gmm, kid_poly};
use srds::model::{EpsModel, GmmEps};
use srds::runtime::{PjrtBackend, PjrtRuntime};
use srds::server::{serve_on, ServeConfig};
use srds::solvers::{NativeBackend, Solver, StepBackend};
use std::io::{BufRead, BufReader, Write};
use std::sync::Arc;

fn artifacts_ready() -> bool {
    srds::artifacts_dir().join("manifest.json").exists()
}

#[test]
fn srds_over_pjrt_matches_native_srds() {
    if !artifacts_ready() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let rt = PjrtRuntime::open_default().unwrap();
    let pjrt = PjrtBackend::new(&rt, "gmm_church", Solver::Ddim).unwrap();
    let native = NativeBackend::new(Arc::new(GmmEps::new(make_gmm("church"))), Solver::Ddim);
    let x0 = prior_sample(64, 3);
    let cfg = SamplerSpec::srds(64).with_tol(1e-4).with_seed(3);
    let a = run_srds(&pjrt, &x0, &cfg);
    let b = run_srds(&native, &x0, &cfg);
    assert_eq!(a.stats.iters, b.stats.iters);
    let d = cfg.norm.dist(&a.sample, &b.sample);
    assert!(d < 5e-3, "pjrt vs native sample diff {d}");
}

#[test]
fn guided_pjrt_sampling_hits_requested_class() {
    if !artifacts_ready() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let rt = PjrtRuntime::open_default().unwrap();
    let be = PjrtBackend::new(&rt, "gmm_latent_cond", Solver::Ddim).unwrap();
    let gmm = make_gmm("latent_cond");
    let cls = 2u32;
    let cond = Conditioning::class(gmm.class_mask(cls), 7.5);
    let x0 = prior_sample(256, 11);
    let cfg = SamplerSpec::srds(25).with_tol(1e-3).with_cond(cond).with_seed(11);
    let res = run_srds(&be, &x0, &cfg);
    // Nearest component must belong to the requested class.
    let d = gmm.dim();
    let mut best = (f32::MAX, 0usize);
    for k in 0..gmm.k() {
        let m = gmm.mean_of(k);
        let dist: f32 = res.sample.iter().zip(m).map(|(a, b)| (a - b) * (a - b)).sum();
        if dist < best.0 {
            best = (dist, k);
        }
    }
    assert_eq!(gmm.comp_class[best.1], cls, "sample landed in wrong class");
    let _ = d;
}

#[test]
fn srds_preserves_sample_quality_fd() {
    // Approximation-free claim: FD(SRDS samples) ≈ FD(sequential samples)
    // at the paper-equivalent tolerance (native backend; the PJRT path is
    // pinned to native by golden tests).
    let gmm = make_gmm("cifar");
    let model: Arc<dyn EpsModel> = Arc::new(GmmEps::new(gmm.clone()));
    let be = NativeBackend::new(model, Solver::Ddim);
    let nsamp = 96;
    let n = 144;
    let mut seq_samples = Vec::with_capacity(nsamp * 64);
    let mut srds_samples = Vec::with_capacity(nsamp * 64);
    let tol = srds::coordinator::convergence::tol_from_pixel255(0.1);
    for s in 0..nsamp as u64 {
        let x0 = prior_sample(64, s);
        let (xs, _) = sequential(&be, &x0, n, &Conditioning::none(), s);
        seq_samples.extend_from_slice(&xs);
        let cfg = SamplerSpec::srds(n).with_tol(tol).with_seed(s);
        let r = run_srds(&be, &x0, &cfg);
        srds_samples.extend_from_slice(&r.sample);
        assert!(r.stats.converged);
    }
    let fd_seq = fd_vs_gmm(&seq_samples, nsamp, &gmm);
    let fd_srds = fd_vs_gmm(&srds_samples, nsamp, &gmm);
    assert!(
        (fd_srds - fd_seq).abs() < 0.15 * (1.0 + fd_seq),
        "fd_srds {fd_srds} vs fd_seq {fd_seq}"
    );
    // And the two sample sets are close in KID terms.
    let kid = kid_poly(&seq_samples, nsamp, &srds_samples, nsamp, 64);
    assert!(kid.abs() < 0.05, "kid between seq and srds sets: {kid}");
}

#[test]
fn tcp_server_round_trip() {
    // Spin the real server on an ephemeral port and run two requests.
    let model: Arc<dyn EpsModel> = Arc::new(GmmEps::new(make_gmm("toy2d")));
    let factory = Arc::new(NativeFactory::new(model, Solver::Ddim));
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let addr2 = addr.clone();
    std::thread::spawn(move || {
        let _ = serve_on(
            listener,
            ServeConfig {
                addr: addr2,
                shards: 1,
                workers: 2,
                model_name: "gmm_toy2d".into(),
                factory,
                batch: srds::batching::BatchPolicy::default(),
                max_inflight: srds::server::DEFAULT_MAX_INFLIGHT,
                default_deadline: None,
                spine_cache_cap: srds::server::DEFAULT_SPINE_CACHE_CAP,
                coalesce: true,
            },
        );
    });
    // Wait for the listener.
    let mut stream = None;
    for _ in 0..50 {
        match std::net::TcpStream::connect(&addr) {
            Ok(s) => {
                stream = Some(s);
                break;
            }
            Err(_) => std::thread::sleep(std::time::Duration::from_millis(20)),
        }
    }
    let stream = stream.expect("server did not come up");
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);
    writeln!(writer, r#"{{"id": 1, "sampler": "srds", "n": 16, "seed": 4}}"#).unwrap();
    writeln!(writer, r#"{{"id": 2, "sampler": "sequential", "n": 16, "seed": 4}}"#).unwrap();
    writer.flush().unwrap();
    drop(writer);
    let mut lines = Vec::new();
    let mut buf = String::new();
    while reader.read_line(&mut buf).unwrap() > 0 {
        lines.push(buf.trim().to_string());
        buf.clear();
        if lines.len() == 2 {
            break;
        }
    }
    assert_eq!(lines.len(), 2);
    let mut samples = Vec::new();
    for line in &lines {
        let v = srds::json::parse(line).unwrap();
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(true), "{line}");
        samples.push(v.get("sample").unwrap().as_f32_vec().unwrap());
    }
    // Same seed → srds ≈ sequential sample (approximation-free serving).
    let diff: f32 = samples[0].iter().zip(&samples[1]).map(|(a, b)| (a - b).abs()).sum();
    assert!(diff / 2.0 < 0.05, "serving samplers disagree: {diff}");
}

#[test]
fn measured_pipelined_with_pjrt_factory() {
    if !artifacts_ready() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let factory =
        srds::runtime::PjrtFactory::new(srds::artifacts_dir(), "gmm_church", Solver::Ddim)
            .unwrap();
    let pool = WorkerPool::new(Arc::new(factory), 3);
    let x0 = prior_sample(64, 21);
    let cfg = SamplerSpec::srds(25).with_tol(1e-3).with_seed(21);
    let res = measured_pipelined_srds(&pool, &x0, &cfg);
    assert!(res.stats.converged);
    assert!(res.sample.iter().all(|v| v.is_finite()));
    assert!(res.stats.wall.as_nanos() > 0);
}

#[test]
fn all_solver_artifacts_drive_srds() {
    if !artifacts_ready() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let rt = PjrtRuntime::open_default().unwrap();
    for solver in [Solver::Ddim, Solver::Ddpm, Solver::Euler, Solver::Heun, Solver::Dpm2] {
        let be = match PjrtBackend::new(&rt, "gmm_latent_cond", solver) {
            Ok(b) => b,
            Err(_) => continue,
        };
        let x0 = prior_sample(256, 2);
        let cfg = SamplerSpec::srds(16).with_tol(1e-2).with_seed(2);
        let res = run_srds(&be, &x0, &cfg);
        assert!(
            res.sample.iter().all(|v| v.is_finite()),
            "{} produced non-finite samples",
            solver.name()
        );
        assert!(res.stats.total_evals > 0);
    }
}
