//! Wire-protocol v1 streaming tests over real TCP: the anytime
//! property as traffic. A `"stream": true` request must produce the
//! strict frame lifecycle — one `ack`, one `iterate` per completed
//! Parareal refinement (each a valid sample), then exactly one
//! terminal `final` — with the final sample bit-identical to the same
//! request served without streaming. A client that vanishes mid-stream
//! must get its request aborted inside the engine (rows purged,
//! per-class `aborted` counted), observed here through the stats
//! probe. The probe itself is pinned to its admission exemption: it
//! answers while a connection sits at `max_inflight`, where a sampling
//! request is shed.
//!
//! The disconnect/saturation tests run against a deliberately slowed
//! model (a sleep per batched eval) so "mid-stream" is a wide, not a
//! racy, window.

use srds::batching::BatchPolicy;
use srds::data::make_gmm;
use srds::exec::NativeFactory;
use srds::json::Value;
use srds::model::{EpsModel, GmmEps};
use srds::server::{serve_on, ServeConfig, DEFAULT_SPINE_CACHE_CAP};
use srds::solvers::Solver;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// GmmEps with a fixed sleep per batched eval call: turns the toy
/// model's microsecond iterates into tens of milliseconds, so tests
/// can act "mid-stream" without racing the sampler.
struct SlowEps {
    inner: GmmEps,
    delay: Duration,
}

impl EpsModel for SlowEps {
    fn dim(&self) -> usize {
        self.inner.dim()
    }

    fn eps(&self, x: &[f32], s: &[f32], mask: Option<&[f32]>, out: &mut [f32]) {
        std::thread::sleep(self.delay);
        self.inner.eps(x, s, mask, out);
    }

    fn k(&self) -> usize {
        self.inner.k()
    }
}

fn spawn_server(model: Arc<dyn EpsModel>, max_inflight: usize) -> String {
    let factory = Arc::new(NativeFactory::new(model, Solver::Ddim));
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let cfg = ServeConfig {
        addr: addr.clone(),
        shards: 2,
        workers: 2,
        model_name: "gmm_toy2d".into(),
        factory,
        batch: BatchPolicy::default(),
        max_inflight,
        default_deadline: None,
        spine_cache_cap: DEFAULT_SPINE_CACHE_CAP,
        coalesce: true,
    };
    std::thread::spawn(move || {
        let _ = serve_on(listener, cfg);
    });
    addr
}

fn connect(addr: &str) -> (TcpStream, BufReader<TcpStream>) {
    let stream = TcpStream::connect(addr).unwrap();
    let writer = stream.try_clone().unwrap();
    (writer, BufReader::new(stream))
}

fn read_frame(reader: &mut BufReader<TcpStream>) -> Value {
    let mut buf = String::new();
    assert!(reader.read_line(&mut buf).unwrap() > 0, "connection closed mid-protocol");
    srds::json::parse(buf.trim()).unwrap_or_else(|e| panic!("bad frame {buf:?}: {e:?}"))
}

fn frame_name(v: &Value) -> String {
    v.get("frame")
        .and_then(|f| f.as_str())
        .unwrap_or_else(|| panic!("frameless line: {v:?}"))
        .to_string()
}

#[test]
fn stream_lifecycle_delivers_every_iterate_then_a_bit_identical_final() {
    let model: Arc<dyn EpsModel> = Arc::new(GmmEps::new(make_gmm("toy2d")));
    let addr = spawn_server(model, 64);
    let (mut writer, mut reader) = connect(&addr);
    // tol 0 + max_iters 4 forces exactly four refinements, so the
    // expected frame count is pinned, not timing-dependent.
    writeln!(
        writer,
        r#"{{"v":1,"id":7,"sampler":"srds","n":25,"seed":23,"tol":0.0,"max_iters":4,"stream":true}}"#
    )
    .unwrap();
    writer.flush().unwrap();

    // 1. The ack comes first, before any iterate.
    let ack = read_frame(&mut reader);
    assert_eq!(frame_name(&ack), "ack", "{ack:?}");
    assert_eq!(ack.get("v").unwrap().as_f64(), Some(1.0));
    assert_eq!(ack.get("id").unwrap().as_f64(), Some(7.0));
    assert_eq!(ack.get("ok").unwrap().as_bool(), Some(true));
    assert_eq!(ack.get("sampler").unwrap().as_str(), Some("srds"));
    assert_eq!(ack.get("stream").unwrap().as_bool(), Some(true));

    // 2. Iterate frames in refinement order, then exactly one final.
    let mut iterates: Vec<(u64, Vec<f32>)> = Vec::new();
    let fin = loop {
        let v = read_frame(&mut reader);
        match frame_name(&v).as_str() {
            "iterate" => {
                assert_eq!(v.get("id").unwrap().as_f64(), Some(7.0), "{v:?}");
                assert_eq!(v.get("ok").unwrap().as_bool(), Some(true));
                let it = v.get("iter").unwrap().as_f64().unwrap() as u64;
                let res = v.get("residual").unwrap().as_f64().unwrap();
                assert!(res.is_finite(), "{v:?}");
                iterates.push((it, v.get("sample").unwrap().as_f32_vec().unwrap()));
            }
            "final" => break v,
            other => panic!("unexpected {other:?} frame mid-stream: {v:?}"),
        }
    };
    assert_eq!(fin.get("id").unwrap().as_f64(), Some(7.0));
    assert_eq!(fin.get("ok").unwrap().as_bool(), Some(true), "{fin:?}");
    let iters = fin.get("iters").unwrap().as_f64().unwrap() as usize;
    assert_eq!(iters, 4, "tol 0 + max_iters 4 runs all four refinements");
    assert_eq!(iterates.len(), iters, "one iterate frame per refinement, none dropped");
    for (k, (it, _)) in iterates.iter().enumerate() {
        assert_eq!(*it, k as u64 + 1, "iterate frames arrive in refinement order");
    }
    assert_eq!(fin.get("converged").unwrap().as_bool(), Some(false), "tol 0 can't converge");
    assert_eq!(fin.get("timed_out").unwrap().as_bool(), Some(false));
    let final_sample = fin.get("sample").unwrap().as_f32_vec().unwrap();
    assert_eq!(
        final_sample,
        iterates.last().unwrap().1,
        "the last iterate IS the final sample (anytime property)"
    );

    // 3. Bit-identity: the same request without streaming — and in the
    // legacy dialect — returns the same sample through the same fleet.
    writeln!(
        writer,
        r#"{{"v":1,"id":8,"sampler":"srds","n":25,"seed":23,"tol":0.0,"max_iters":4}}"#
    )
    .unwrap();
    writer.flush().unwrap();
    let single = read_frame(&mut reader);
    assert_eq!(frame_name(&single), "final");
    assert_eq!(
        single.get("sample").unwrap().as_f32_vec().unwrap(),
        final_sample,
        "stream vs non-stream must be bit-identical"
    );
    writeln!(writer, r#"{{"id":9,"sampler":"srds","n":25,"seed":23,"tol":0.0,"max_iters":4}}"#)
        .unwrap();
    writer.flush().unwrap();
    let legacy = read_frame(&mut reader);
    assert!(legacy.get("frame").is_none(), "v0 responses carry no envelope: {legacy:?}");
    assert_eq!(
        legacy.get("sample").unwrap().as_f32_vec().unwrap(),
        final_sample,
        "legacy dialect vs stream must be bit-identical"
    );
}

#[test]
fn stream_with_zero_timeout_finalizes_from_the_coarse_init() {
    // timeout_ms: 0 expires on the dispatcher's first sweep: the
    // stream is acked, completes zero refinements, and the terminal
    // frame is an honest timed-out final built from the coarse spine.
    let model: Arc<dyn EpsModel> = Arc::new(GmmEps::new(make_gmm("toy2d")));
    let addr = spawn_server(model, 64);
    let (mut writer, mut reader) = connect(&addr);
    writeln!(
        writer,
        r#"{{"v":1,"id":3,"sampler":"srds","n":25,"seed":31,"tol":0.0,"max_iters":4,"stream":true,"timeout_ms":0}}"#
    )
    .unwrap();
    writer.flush().unwrap();
    let ack = read_frame(&mut reader);
    assert_eq!(frame_name(&ack), "ack", "{ack:?}");
    let fin = read_frame(&mut reader);
    assert_eq!(frame_name(&fin), "final", "no iterate can complete before a 0ms deadline");
    assert_eq!(fin.get("ok").unwrap().as_bool(), Some(true), "{fin:?}");
    assert_eq!(fin.get("timed_out").unwrap().as_bool(), Some(true), "{fin:?}");
    assert_eq!(fin.get("converged").unwrap().as_bool(), Some(false));
    assert_eq!(fin.get("iters").unwrap().as_f64(), Some(0.0));
    let sample = fin.get("sample").unwrap().as_f32_vec().unwrap();
    assert!(sample.iter().all(|x| x.is_finite()), "{fin:?}");
}

#[test]
fn stats_probe_answers_at_max_inflight_while_sampling_is_shed() {
    // One admission slot, held by a deliberately slow stream. The
    // probe must answer (its typed exemption), while a second sampling
    // request is shed with the structured overloaded frame.
    let model: Arc<dyn EpsModel> = Arc::new(SlowEps {
        inner: GmmEps::new(make_gmm("toy2d")),
        delay: Duration::from_millis(2),
    });
    let addr = spawn_server(model, 1);
    let (mut writer, mut reader) = connect(&addr);
    writeln!(
        writer,
        r#"{{"v":1,"id":1,"sampler":"srds","n":16,"seed":5,"tol":0.0,"max_iters":8,"stream":true}}"#
    )
    .unwrap();
    // While that stream occupies the only slot: a sampling request
    // (shed) and a stats probe (answered).
    writeln!(writer, r#"{{"v":1,"id":2,"sampler":"srds","n":16,"seed":6}}"#).unwrap();
    writeln!(writer, r#"{{"v":1,"id":3,"kind":"stats"}}"#).unwrap();
    writer.flush().unwrap();

    let (mut saw_shed, mut saw_stats, mut saw_final) = (false, false, false);
    while !(saw_shed && saw_stats) {
        let v = read_frame(&mut reader);
        match frame_name(&v).as_str() {
            "error" => {
                assert_eq!(v.get("id").unwrap().as_f64(), Some(2.0), "{v:?}");
                assert_eq!(v.get("kind").unwrap().as_str(), Some("overloaded"), "{v:?}");
                assert_eq!(v.get("max_inflight").unwrap().as_f64(), Some(1.0));
                assert!(v.get("retry_after_ms").unwrap().as_f64().unwrap() > 0.0);
                saw_shed = true;
            }
            "stats" => {
                assert_eq!(v.get("id").unwrap().as_f64(), Some(3.0), "{v:?}");
                assert_eq!(v.get("ok").unwrap().as_bool(), Some(true));
                assert_eq!(v.get("shards").unwrap().as_f64(), Some(2.0));
                saw_stats = true;
            }
            // The stream keeps streaming around the probe traffic.
            "ack" | "iterate" => {}
            "final" => saw_final = true,
            other => panic!("unexpected {other:?}: {v:?}"),
        }
    }
    assert!(
        !saw_final,
        "probe and shed must answer while the stream still holds the slot"
    );
}

#[test]
fn mid_stream_disconnect_aborts_the_request_in_the_engine() {
    let model: Arc<dyn EpsModel> = Arc::new(SlowEps {
        inner: GmmEps::new(make_gmm("toy2d")),
        delay: Duration::from_millis(2),
    });
    let addr = spawn_server(model, 64);
    {
        let (mut writer, mut reader) = connect(&addr);
        // Several distinct slow streams (distinct seeds — no
        // coalescing), so work is certainly resident at disconnect.
        for (i, seed) in [(1u64, 100u64), (2, 101), (3, 102)] {
            writeln!(
                writer,
                r#"{{"v":1,"id":{i},"sampler":"srds","n":16,"seed":{seed},"tol":0.0,"max_iters":10,"stream":true}}"#
            )
            .unwrap();
        }
        writer.flush().unwrap();
        // Wait until the streams are demonstrably live: three acks and
        // at least one iterate have crossed the wire.
        let (mut acks, mut iterates) = (0u32, 0u32);
        while acks < 3 || iterates < 1 {
            let v = read_frame(&mut reader);
            match frame_name(&v).as_str() {
                "ack" => acks += 1,
                "iterate" => iterates += 1,
                "final" => panic!("slow stream finished before the disconnect: {v:?}"),
                other => panic!("unexpected {other:?}: {v:?}"),
            }
        }
        // Drop both halves: the poll loop's next write to this
        // connection fails, flips the liveness flag, and the owning
        // dispatchers abort the still-running tasks.
    }
    // Observe the abort from a fresh connection via the stats probe.
    let (mut writer, mut reader) = connect(&addr);
    let t0 = Instant::now();
    loop {
        writeln!(writer, r#"{{"kind":"stats","id":9}}"#).unwrap();
        writer.flush().unwrap();
        let v = read_frame(&mut reader);
        let lane = v.get("classes").unwrap().get("standard").unwrap();
        let aborted = lane.get("aborted").unwrap().as_f64().unwrap();
        let active = v.get("active_tasks").unwrap().as_f64().unwrap();
        if aborted >= 1.0 && active == 0.0 {
            // Rows were purged with the tasks: the queue drains to
            // empty rather than grinding through orphaned work.
            assert_eq!(v.get("queue_depth").unwrap().as_f64(), Some(0.0), "{v:?}");
            break;
        }
        assert!(
            t0.elapsed() < Duration::from_secs(30),
            "disconnect never aborted the streams: {v:?}"
        );
        std::thread::sleep(Duration::from_millis(20));
    }
}
