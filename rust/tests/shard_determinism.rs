//! Cross-shard determinism: the router's placement and the mesh's work
//! stealing are *pure scheduling* — they move queued step rows between
//! dispatchers and workers, never between rows. Batch rows do not
//! interact and every backend computes each row independently, so a
//! request's output must be bit-identical (`assert_eq!` on the f32
//! sample, no tolerance) whichever shard runs it, at any fleet width,
//! with stealing on or off. This is the serving-level extension of the
//! batch-shape invariant pinned in `batch_shape.rs`: batch composition
//! there, shard/steal placement here, same contract.

use srds::coordinator::{prior_sample, QosClass, SamplerSpec};
use srds::data::make_gmm;
use srds::exec::{NativeFactory, Router, RouterConfig};
use srds::model::{EpsModel, GmmEps};
use srds::solvers::{NativeBackend, Solver};
use std::sync::Arc;

fn fleet(shards: usize, steal: bool) -> Router {
    let model: Arc<dyn EpsModel> = Arc::new(GmmEps::new(make_gmm("toy2d")));
    Router::new(
        Arc::new(NativeFactory::new(model, Solver::Ddim)),
        // One worker per shard: the narrowest fleet, where any
        // scheduling effect on numerics would be easiest to expose.
        RouterConfig { shards, workers: 1, steal, ..RouterConfig::default() },
    )
}

/// The reference: the same spec run solo on a dedicated single-tenant
/// backend — no engine, no batching, no fleet.
fn solo(x0: &[f32], spec: &SamplerSpec) -> Vec<f32> {
    let model: Arc<dyn EpsModel> = Arc::new(GmmEps::new(make_gmm("toy2d")));
    let be = NativeBackend::new(model, Solver::Ddim);
    spec.run(&be, x0).sample
}

#[test]
fn pinned_first_and_last_shard_agree_bitwise_with_solo() {
    // The same spec pinned to shard 0 and to shard N−1 of a 3-shard
    // fleet: both must reproduce the solo run exactly, for every
    // sampler kind (each schedules its rows differently).
    let r = fleet(3, false);
    let last = r.shards() - 1;
    let specs = [
        SamplerSpec::srds(25).with_tol(1e-5),
        SamplerSpec::sequential(16),
        SamplerSpec::paradigms(32).with_tol(1e-6),
        SamplerSpec::parataa(16).with_tol(1e-6),
    ];
    for (i, base) in specs.into_iter().enumerate() {
        let seed = 900 + i as u64;
        let spec = base.with_seed(seed).with_priority(QosClass::Interactive);
        let x0 = prior_sample(r.dim(), seed);
        // Submit to both shards concurrently so their rows are in the
        // fleet at the same time, then block for both.
        let first_rx = r.submit_to(0, x0.clone(), spec.clone());
        let last_rx = r.submit_to(last, x0.clone(), spec.clone());
        let want = solo(&x0, &spec);
        let a = first_rx.recv().expect("shard 0 reply");
        let b = last_rx.recv().expect("last shard reply");
        assert_eq!(a.sample, want, "spec {i}: shard 0 diverged from solo");
        assert_eq!(b.sample, want, "spec {i}: shard {last} diverged from solo");
    }
}

#[test]
fn stealing_on_vs_off_is_invisible_in_every_output() {
    // Two identical fleets, one with the steal mesh enabled, fed the
    // same requests all pinned to shard 0 — on the stealing fleet,
    // shard 1 sits idle next to a saturated sibling, which is exactly
    // the trigger for lifting queued rows across the mesh. Whether or
    // not rows migrated, every output must equal the solo run bitwise.
    //
    // Steal liveness is timing-dependent (the idle dispatcher has to
    // poll while the victim is saturated), so the liveness claim gets a
    // few attempts; the bit-identity claim is asserted on every attempt
    // unconditionally — a single divergence fails the test outright.
    let mut stole = false;
    for attempt in 0..5 {
        let on = fleet(2, true);
        let off = fleet(2, false);
        let reqs: Vec<(Vec<f32>, SamplerSpec)> = (0..8u64)
            .map(|s| {
                // Wide ParaDiGMS sweeps: each request queues a whole
                // window of rows at once, giving a 1-worker shard a
                // deep backlog worth stealing from.
                let spec = SamplerSpec::paradigms(64).with_tol(1e-6).with_seed(910 + s);
                (prior_sample(on.dim(), 910 + s), spec)
            })
            .collect();
        let rx_on: Vec<_> =
            reqs.iter().map(|(x0, s)| on.submit_to(0, x0.clone(), s.clone())).collect();
        let rx_off: Vec<_> =
            reqs.iter().map(|(x0, s)| off.submit_to(0, x0.clone(), s.clone())).collect();
        for (i, ((a, b), (x0, spec))) in
            rx_on.into_iter().zip(rx_off).zip(reqs.iter()).enumerate()
        {
            let a = a.recv().expect("steal-on reply");
            let b = b.recv().expect("steal-off reply");
            let want = solo(x0, spec);
            assert_eq!(a.sample, want, "attempt {attempt}, req {i}: stealing fleet diverged");
            assert_eq!(b.sample, want, "attempt {attempt}, req {i}: steal-off fleet diverged");
        }
        let st_on = on.stats();
        let st_off = off.stats();
        assert_eq!(st_off.steals, 0, "steal-off fleet must never migrate rows");
        assert_eq!(
            st_on.per_class.iter().map(|l| l.completed).sum::<u64>(),
            reqs.len() as u64
        );
        if st_on.steals > 0 {
            stole = true;
            break;
        }
    }
    assert!(
        stole,
        "5 attempts of 8 wide sweeps pinned to a 1-worker shard never triggered a steal — \
         the mesh is dead, not just unlucky"
    );
}
