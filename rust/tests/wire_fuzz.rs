//! Malformed-frame fuzz corpus for the lazy request reader.
//!
//! The wire layer parses every inbound line with
//! [`srds::json::lazy::LazyObj`], a single structural pass that indexes
//! field spans without building a tree. Its contract (see the module
//! doc) is exact parity with the tree parser, and this test is the
//! enforcement: for a hand-written corpus of hostile lines plus
//! deterministic mutations of realistic request lines,
//!
//! * **acceptance parity** — the lazy reader accepts a line iff
//!   [`srds::json::parse`] accepts it AND the document is a top-level
//!   object (the wire protocol's framing unit);
//! * **extraction parity** — on every accepted line, `get`/`num`/`has`/
//!   `keys` agree with the tree parse key-for-key, including last-wins
//!   duplicate resolution;
//! * **no panics** — neither parser may panic on any input, however
//!   mangled (truncated surrogates and lone `\u` fragments included:
//!   those were once wire-reachable parser panics).
//!
//! The mutation engine is a seeded xorshift — every run exercises the
//! identical mutant set, so a failure here reproduces byte-for-byte.

use srds::json::{lazy::LazyObj, Value};
use std::collections::BTreeSet;

/// The single oracle: whatever `line` is, the two parsers must agree.
fn check(line: &str) {
    let tree = srds::json::parse(line);
    let lazy = LazyObj::parse(line);
    let tree_obj = match &tree {
        Ok(Value::Obj(m)) => Some(m),
        _ => None,
    };
    match (&lazy, &tree_obj) {
        (Ok(_), None) => panic!(
            "lazy reader accepted a line the tree parser refuses (or a non-object): {line:?}"
        ),
        (Err(e), Some(_)) => {
            panic!("lazy reader rejected a valid object line: {line:?} ({e:?})")
        }
        _ => {}
    }
    let (Ok(lazy), Some(map)) = (lazy, tree_obj) else { return };
    for (k, want) in map.iter() {
        assert!(lazy.has(k), "has({k:?}) false on {line:?}");
        assert_eq!(
            lazy.get(k).as_ref(),
            Some(want),
            "extraction mismatch for key {k:?} in {line:?}"
        );
        assert_eq!(lazy.num(k), want.as_f64(), "num({k:?}) mismatch in {line:?}");
    }
    // keys() may repeat duplicates (source order); as a set it must be
    // exactly the tree's key set.
    let got: BTreeSet<String> = lazy.keys().collect();
    let want: BTreeSet<String> = map.keys().cloned().collect();
    assert_eq!(got, want, "key set mismatch in {line:?}");
    assert!(!lazy.has("\u{1f980}-definitely-absent"));
    assert!(lazy.get("\u{1f980}-definitely-absent").is_none());
}

/// Realistic request lines — the seeds the mutation engine mangles.
const SEEDS: [&str; 8] = [
    r#"{"id":7,"sampler":"srds","n":25,"seed":23,"tol":1e-5,"max_iters":6}"#,
    r#"{"v":1,"id":1,"sampler":"srds","n":25,"stream":true,"timeout_ms":250}"#,
    r#"{"id":2,"kind":"stats"}"#,
    r#"{"v":1,"id":3,"sampler":"paradigms","window":6,"class":2,"guidance":1.5,"norm":"linf"}"#,
    r#"{"id":4,"sampler":"parataa","history":3,"priority":"interactive","deadline":120}"#,
    r#"{"id":5,"sampler":"sequential","n":50,"seed":-17,"sample":false,"iterates":true}"#,
    r#"{ "id" : 6 , "block" : 5 , "tol" : 2.5e-3 }"#,
    r#"{"\u0069d":8,"s":"\ud834\udd1e \n \" \\ é","empty":{},"arr":[1,[2,{"x":null}],true]}"#,
];

#[test]
fn corpus_of_hostile_lines_never_panics_and_parsers_agree() {
    // Hand-written hostiles: every class of damage the wire can carry.
    // Structural truncation, stray separators, bad literals, number
    // garbage, escape/surrogate damage, non-object documents, trailing
    // garbage, duplicate and escaped-duplicate keys.
    let corpus: [&str; 58] = [
        "",
        " ",
        "\t\r\n",
        "{",
        "}",
        "{}",
        "{ }",
        "{{}}",
        "{}{}",
        "{} ",
        " {}",
        "null",
        "true",
        "false",
        "42",
        "-0.5e3",
        r#""just a string""#,
        "[1, 2, 3]",
        r#"[{"id": 1}]"#,
        r#"{"id"}"#,
        r#"{"id":}"#,
        r#"{"id":1,}"#,
        r#"{,"id":1}"#,
        r#"{"id" 1}"#,
        r#"{"id"::1}"#,
        r#"{id: 1}"#,
        r#"{'id': 1}"#,
        r#"{"id": 1"#,
        r#"{"id": 1} trailing"#,
        r#"{"id": 1}{"id": 2}"#,
        r#"{"a": [1, 2}"#,
        r#"{"a": [1, 2]]}"#,
        r#"{"a": {"b": 1}"#,
        r#"{"a": tru}"#,
        r#"{"a": nul}"#,
        r#"{"a": truex}"#,
        r#"{"a": -}"#,
        r#"{"a": 1e}"#,
        r#"{"a": 1e+}"#,
        r#"{"a": 1.2.3}"#,
        r#"{"a": 1e309}"#,
        r#"{"a": -1e-309}"#,
        r#"{"a": 01}"#,
        r#"{"a": +1}"#,
        r#"{"a": .5}"#,
        r#"{"a": "unterminated"#,
        "{\"a\": \"bad escape \\q\"}",
        "{\"a\": \"trunc \\",
        "{\"a\": \"trunc \\u12\"}",
        "{\"a\": \"\\uD800 lone high\"}",
        "{\"a\": \"\\uDC00 lone low\"}",
        "{\"a\": \"\\uD834\\uD834 high high\"}",
        "{\"a\": \"\\uD834\\udd1e ok pair\"}",
        "{\"a\": \"\\uFFFF\"}",
        "{\"a\": \"raw \u{7f} control\"}",
        r#"{"n": 1, "n": 2, "n": 3}"#,
        "{\"a\": 1, \"\\u0061\": 2}",
        r#"{"": 1}"#,
    ];
    for line in corpus {
        check(line);
    }
    for line in SEEDS {
        check(line);
    }
}

/// Deterministic xorshift64 — seeded, so every CI run fuzzes the exact
/// same mutant set and any failure reproduces from the printed line.
struct Rng(u64);

impl Rng {
    fn next(&mut self) -> u64 {
        let mut x = self.0;
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        self.0 = x;
        x
    }

    fn below(&mut self, n: usize) -> usize {
        (self.next() % n.max(1) as u64) as usize
    }
}

#[test]
fn mutated_request_lines_never_split_the_parsers() {
    // Bytes with structural meaning: mutations drawn from this set hit
    // parser decision points far more often than uniform noise.
    const SPICE: &[u8] = b"{}[]\",:\\u-+.eE0123456789 \tnt";
    let mut rng = Rng(0x5eed_cafe_f00d_0001);
    let mut mutants = 0u32;
    for seed in SEEDS {
        for _ in 0..400 {
            let mut bytes = seed.as_bytes().to_vec();
            for _ in 0..1 + rng.below(3) {
                if bytes.is_empty() {
                    break;
                }
                match rng.below(5) {
                    // Overwrite one byte with a structural one.
                    0 => {
                        let i = rng.below(bytes.len());
                        bytes[i] = SPICE[rng.below(SPICE.len())];
                    }
                    // Delete one byte.
                    1 => {
                        bytes.remove(rng.below(bytes.len()));
                    }
                    // Insert a structural byte.
                    2 => {
                        let i = rng.below(bytes.len() + 1);
                        bytes.insert(i, SPICE[rng.below(SPICE.len())]);
                    }
                    // Truncate (the torn-frame case: a client dying
                    // mid-write is the most common real-world mangle).
                    3 => {
                        bytes.truncate(rng.below(bytes.len() + 1));
                    }
                    // Duplicate a random span in place (repeated keys,
                    // doubled separators, cloned values).
                    4 => {
                        let a = rng.below(bytes.len());
                        let b = (a + 1 + rng.below(8)).min(bytes.len());
                        let span = bytes[a..b].to_vec();
                        let i = rng.below(bytes.len() + 1);
                        bytes.splice(i..i, span);
                    }
                    _ => unreachable!(),
                }
            }
            // Both parsers take &str; non-UTF-8 mutants can't reach
            // them over the line-based wire (read_line hands out
            // String), so skip those rather than test a dead path.
            let Ok(line) = String::from_utf8(bytes) else { continue };
            mutants += 1;
            check(&line);
        }
    }
    assert!(mutants > 2000, "mutation engine degenerated: only {mutants} valid mutants");
}
