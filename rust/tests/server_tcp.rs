//! TCP-level serving test for the multi-tenant engine: several
//! concurrent connections with interleaved samplers and seeds, every
//! response id-correlated, and every served sample identical to a solo
//! single-request run — the engine's equivalence invariant, observed
//! through the real wire protocol. Since the engine-native task rework
//! the serve loop runs every request (all four registry samplers at
//! once here) on the engine's dispatcher + worker threads only — there
//! is no per-request thread for this test to accidentally depend on.
//!
//! The server runs with a tight `max_inflight = 2` admission gate while
//! each client pipelines four requests, so the gate's shed path is
//! exercised for real: over-cap requests come back *immediately* as
//! structured `error_kind: "overloaded"` lines (the read loop never
//! stalls — the pre-QoS behavior of parking the connection gave clients
//! nothing to back off on), and the clients here do what a production
//! client would: correlate the shed id, back off, resend. Every request
//! eventually succeeds and every sample still matches its solo run.

use srds::batching::BatchPolicy;
use srds::data::make_gmm;
use srds::exec::NativeFactory;
use srds::model::{EpsModel, GmmEps};
use srds::server::{handle_line, serve_on, ServeConfig};
use srds::solvers::{BackendFactory, Solver};
use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::sync::Arc;

#[test]
fn concurrent_tcp_clients_get_solo_equivalent_samples() {
    let model: Arc<dyn EpsModel> = Arc::new(GmmEps::new(make_gmm("toy2d")));
    let factory = Arc::new(NativeFactory::new(model.clone(), Solver::Ddim));
    // Bind the ephemeral port first, then hand the live listener to the
    // server — no drop-and-rebind race.
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    {
        let cfg = ServeConfig {
            addr: addr.clone(),
            // Two shards so the poll loop + router + steal mesh serve
            // this test's mixed fleet — samples must still match the
            // solo single-tenant runs bit-for-bit.
            shards: 2,
            workers: 2,
            model_name: "gmm_toy2d".into(),
            factory: factory.clone(),
            batch: BatchPolicy::default(),
            // A tight per-connection admission cap: with 4 pipelined
            // requests per client the shed path fires and the clients
            // must retry on the structured overloaded error.
            max_inflight: 2,
            default_deadline: None,
            spine_cache_cap: srds::server::DEFAULT_SPINE_CACHE_CAP,
            coalesce: true,
        };
        std::thread::spawn(move || {
            let _ = serve_on(listener, cfg);
        });
    }

    const SAMPLERS: [&str; 4] = ["srds", "sequential", "paradigms", "parataa"];
    let mut clients = Vec::new();
    for c in 0..3u64 {
        let addr = addr.clone();
        clients.push(std::thread::spawn(move || {
            let stream = std::net::TcpStream::connect(&addr).unwrap();
            let mut writer = stream.try_clone().unwrap();
            let mut reader = BufReader::new(stream);
            // Pipeline four requests per connection, cycling samplers so
            // different kinds are in flight at once across clients.
            let mut lines: HashMap<u64, String> = HashMap::new();
            for j in 0..4u64 {
                let id = c * 100 + j;
                let sampler = SAMPLERS[((c + j) % 4) as usize];
                let line = format!(
                    r#"{{"id":{id},"sampler":"{sampler}","n":25,"seed":{seed},"tol":1e-5}}"#,
                    seed = 1000 + id
                );
                writeln!(writer, "{line}").unwrap();
                lines.insert(id, line);
            }
            writer.flush().unwrap();
            // Responses stream back in completion order; correlate by
            // id. Overloaded sheds are retried (with a short backoff) —
            // the gate guarantees progress, so every id succeeds
            // eventually.
            let mut got: HashMap<u64, Vec<f32>> = HashMap::new();
            let mut sheds = 0u32;
            let mut buf = String::new();
            while got.len() < lines.len() && reader.read_line(&mut buf).unwrap() > 0 {
                let v = srds::json::parse(buf.trim()).unwrap();
                let id = v.get("id").unwrap().as_f64().unwrap() as u64;
                if v.get("ok").unwrap().as_bool() == Some(false) {
                    // The only acceptable failure is the structured
                    // admission shed; anything else is a real bug.
                    assert_eq!(
                        v.get("error_kind").and_then(|k| k.as_str()),
                        Some("overloaded"),
                        "unexpected error line: {buf}"
                    );
                    assert_eq!(v.get("max_inflight").unwrap().as_f64(), Some(2.0), "{buf}");
                    sheds += 1;
                    assert!(sheds < 1000, "admission gate never admitted id {id}");
                    std::thread::sleep(std::time::Duration::from_millis(2));
                    writeln!(writer, "{}", lines[&id]).unwrap();
                    writer.flush().unwrap();
                    buf.clear();
                    continue;
                }
                // Legacy-dialect pin: these requests carry no "v", so
                // the redesigned wire layer must answer in the
                // historical single-frame shape — no envelope keys.
                assert!(
                    v.get("v").is_none() && v.get("frame").is_none(),
                    "v0 response grew envelope keys: {buf}"
                );
                // The wall-clock timeout field rides every response
                // (false here: these requests are unbudgeted).
                assert_eq!(v.get("timed_out").unwrap().as_bool(), Some(false), "{buf}");
                assert!(
                    v.get("batch_occupancy").unwrap().as_f64().unwrap() >= 1.0,
                    "{buf}"
                );
                // The task-table depth gauge rides every engine response.
                assert!(v.get("active_tasks").unwrap().as_f64().unwrap() >= 0.0, "{buf}");
                // So do the QoS fields (these requests are all standard).
                assert_eq!(v.get("priority").unwrap().as_str(), Some("standard"), "{buf}");
                assert_eq!(v.get("deadline_hit").unwrap().as_bool(), Some(false), "{buf}");
                let sample = v.get("sample").unwrap().as_f32_vec().unwrap();
                let fresh = got.insert(id, sample).is_none();
                assert!(fresh, "duplicate response for id {id}");
                buf.clear();
            }
            (lines, got)
        }));
    }

    // Solo references on a dedicated backend — the single-tenant path.
    let be = NativeFactory::new(model, Solver::Ddim).create();
    for t in clients {
        let (lines, got) = t.join().unwrap();
        assert_eq!(got.len(), lines.len(), "missing responses");
        for (id, line) in lines {
            let reference =
                srds::json::parse(&handle_line(be.as_ref(), "gmm_toy2d", &line)).unwrap();
            let want = reference.get("sample").unwrap().as_f32_vec().unwrap();
            let sample = &got[&id];
            let d: f32 = want
                .iter()
                .zip(sample)
                .map(|(a, b)| (a - b).abs())
                .sum::<f32>()
                / want.len().max(1) as f32;
            assert!(d < 1e-6, "request {id} ({line}): served vs solo diff {d}");
        }
    }
}
