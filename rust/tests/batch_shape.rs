//! Batch-shape invariance properties — the contract the kernel layer
//! (`srds::kernels`) and the engine's data-parallel batch splitting
//! stand on: **rows never interact**. A row's stepped output must be
//! bit-identical whatever batch it rides in — batch size 1/3/8/32
//! (ragged tails included), any contiguous chunk split a dispatcher
//! might choose — for all five solvers on both model families (the
//! analytic GMM score and the `SmallDenoiser` MLP), guided and not.
//!
//! Everything here is `assert_eq!` on `f32` slices: tolerances would
//! hide exactly the class of bug (row math depending on batch
//! composition) these tests exist to catch.

use srds::data::make_gmm;
use srds::data::rng::SplitMix64;
use srds::model::{EpsModel, GmmEps, SmallDenoiser};
use srds::solvers::{NativeBackend, Solver, StepBackend, StepRequest};
use std::sync::Arc;

/// Deterministic per-row inputs: states, schedule positions, seeds.
/// Rows deliberately sit at unrelated schedule positions so fused
/// coefficient staging cannot accidentally share work across rows.
fn make_rows(d: usize, b: usize, salt: u64) -> (Vec<f32>, Vec<f32>, Vec<f32>, Vec<u64>) {
    let mut rng = SplitMix64::new(0xba7c4_5a9e ^ salt);
    let x = rng.normals_f32(b * d);
    let mut s_from = Vec::with_capacity(b);
    let mut s_to = Vec::with_capacity(b);
    let mut seeds = Vec::with_capacity(b);
    for i in 0..b {
        // Spread over (0, 0.9) with irregular spacing and step sizes.
        let f = 0.03 + 0.87 * ((i * 37 + 11) % 100) as f32 / 100.0;
        s_from.push(f);
        s_to.push((f + 0.01 + 0.05 * ((i * 13) % 7) as f32 / 7.0).min(0.98));
        seeds.push(salt.wrapping_mul(1000) + i as u64);
    }
    (x, s_from, s_to, seeds)
}

fn req<'a>(x: &'a [f32], s_from: &'a [f32], s_to: &'a [f32], seeds: &'a [u64]) -> StepRequest<'a> {
    StepRequest { x, s_from, s_to, mask: None, guidance: 0.0, seeds }
}

fn models() -> Vec<(&'static str, Arc<dyn EpsModel>)> {
    vec![
        ("gmm_church_d64", Arc::new(GmmEps::new(make_gmm("church"))) as Arc<dyn EpsModel>),
        ("gmm_toy2d_d2", Arc::new(GmmEps::new(make_gmm("toy2d"))) as Arc<dyn EpsModel>),
        ("denoiser_d19", Arc::new(SmallDenoiser::new(19)) as Arc<dyn EpsModel>),
        ("denoiser_d64", Arc::new(SmallDenoiser::new(64)) as Arc<dyn EpsModel>),
    ]
}

/// Solo references: each row stepped alone through a fresh request.
fn solo_rows(
    be: &NativeBackend,
    d: usize,
    x: &[f32],
    s_from: &[f32],
    s_to: &[f32],
    seeds: &[u64],
) -> Vec<f32> {
    let b = s_from.len();
    let mut out = vec![0.0f32; b * d];
    for i in 0..b {
        be.step_into(
            &req(&x[i * d..(i + 1) * d], &s_from[i..=i], &s_to[i..=i], &seeds[i..=i]),
            &mut out[i * d..(i + 1) * d],
        );
    }
    out
}

#[test]
fn row_outputs_are_bit_identical_across_batch_sizes() {
    for (name, model) in models() {
        let d = model.dim();
        for solver in Solver::ALL {
            let be = NativeBackend::new(model.clone(), solver);
            // 32 reference rows, stepped solo.
            let (x, s_from, s_to, seeds) = make_rows(d, 32, solver as u64);
            let want = solo_rows(&be, d, &x, &s_from, &s_to, &seeds);
            // The same rows grouped into batches of 1 / 3 / 8 / 32 —
            // 3 leaves a ragged tail (32 = 10*3 + 2), 8 and 32 are
            // lane-aligned, 1 is the solo degenerate case.
            for bs in [1usize, 3, 8, 32] {
                let mut got = vec![0.0f32; 32 * d];
                let mut r = 0;
                while r < 32 {
                    let e = (r + bs).min(32);
                    be.step_into(
                        &req(&x[r * d..e * d], &s_from[r..e], &s_to[r..e], &seeds[r..e]),
                        &mut got[r * d..e * d],
                    );
                    r = e;
                }
                assert_eq!(
                    got,
                    want,
                    "{name}/{}: batch size {bs} changed some row's bits",
                    solver.name()
                );
            }
        }
    }
}

#[test]
fn row_outputs_survive_worker_chunk_splits() {
    // The engine may split one drained batch into contiguous row-chunk
    // sub-batches across idle workers (uneven chunks included). Every
    // split of a 32-row batch must reproduce the fused batch bitwise.
    for (name, model) in models() {
        let d = model.dim();
        for solver in Solver::ALL {
            let be = NativeBackend::new(model.clone(), solver);
            let (x, s_from, s_to, seeds) = make_rows(d, 32, 77 + solver as u64);
            let mut fused = vec![0.0f32; 32 * d];
            be.step_into(&req(&x, &s_from, &s_to, &seeds), &mut fused);
            // Chunk layouts a 4-worker flush could produce: even 8s,
            // div_ceil spreading of 30-ish rows, and a lopsided split.
            let layouts: [&[usize]; 4] = [&[8, 8, 8, 8], &[9, 9, 9, 5], &[20, 12], &[31, 1]];
            for splits in layouts {
                let mut got = vec![0.0f32; 32 * d];
                let mut r = 0;
                for len in splits.iter().copied() {
                    let e = r + len;
                    be.step_into(
                        &req(&x[r * d..e * d], &s_from[r..e], &s_to[r..e], &seeds[r..e]),
                        &mut got[r * d..e * d],
                    );
                    r = e;
                }
                assert_eq!(r, 32, "split layout must cover the batch");
                assert_eq!(
                    got,
                    fused,
                    "{name}/{}: chunk split {splits:?} changed some row's bits",
                    solver.name()
                );
            }
        }
    }
}

#[test]
fn guided_rows_are_bit_identical_across_batch_sizes() {
    // Same property through the fused guided path: per-row class masks
    // and a strong guidance weight, batched vs solo.
    let gmm = make_gmm("latent_cond");
    let model: Arc<dyn EpsModel> = Arc::new(GmmEps::new(gmm.clone()));
    let d = model.dim();
    let k = model.k();
    for solver in [Solver::Ddim, Solver::Heun] {
        let be = NativeBackend::new(model.clone(), solver);
        let (x, s_from, s_to, seeds) = make_rows(d, 8, 5 + solver as u64);
        let mask: Vec<f32> = (0..8).flat_map(|i| gmm.class_mask((i % 2) as u32)).collect();
        assert_eq!(mask.len(), 8 * k);
        let mut want = vec![0.0f32; 8 * d];
        for i in 0..8 {
            be.step_into(
                &StepRequest {
                    x: &x[i * d..(i + 1) * d],
                    s_from: &s_from[i..=i],
                    s_to: &s_to[i..=i],
                    mask: Some(&mask[i * k..(i + 1) * k]),
                    guidance: 7.5,
                    seeds: &seeds[i..=i],
                },
                &mut want[i * d..(i + 1) * d],
            );
        }
        for bs in [3usize, 8] {
            let mut got = vec![0.0f32; 8 * d];
            let mut r = 0;
            while r < 8 {
                let e = (r + bs).min(8);
                be.step_into(
                    &StepRequest {
                        x: &x[r * d..e * d],
                        s_from: &s_from[r..e],
                        s_to: &s_to[r..e],
                        mask: Some(&mask[r * k..e * k]),
                        guidance: 7.5,
                        seeds: &seeds[r..e],
                    },
                    &mut got[r * d..e * d],
                );
                r = e;
            }
            assert_eq!(
                got,
                want,
                "guided {}: batch size {bs} changed some row's bits",
                solver.name()
            );
        }
    }
}
