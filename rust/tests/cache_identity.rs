//! Shared-work layer contracts (`exec::engine`'s coarse-spine cache +
//! in-flight coalescing):
//!
//! * **Bit-identity is the hard line.** A cached-spine warm start and a
//!   coalesced fan-out reply must both equal the fresh solo run on the
//!   raw f32 sample (`assert_eq!`, no tolerance) — the cache and the
//!   dedupe table are pure work-sharing, never an approximation.
//! * **The warm start actually skips work**: a repeat request's
//!   `eff_serial_evals` drops by the skipped coarse sweep (the zero
//!   spine-row pin lives next to the task machine, in
//!   `exec::task`'s `warm_spine_task_matches_fresh_bitwise_and_skips_the_spine`).
//! * **Cancellation detaches followers, not tasks**: a coalesced
//!   duplicate whose client dies must not kill the run its siblings
//!   still await.
//! * **Retention is bounded**: the cache holds at most `cap` spines
//!   (QoS-aware LRU), so a parade of distinct specs cannot grow the
//!   live buffer set — the `pool_soak.rs` invariant extended to a
//!   cache-enabled engine.

use srds::coordinator::{prior_sample, QosClass, SamplerSpec};
use srds::data::make_gmm;
use srds::exec::{Engine, EngineConfig, NativeFactory};
use srds::model::{EpsModel, GmmEps};
use srds::solvers::{NativeBackend, Solver};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::channel;
use std::sync::Arc;

fn engine(workers: usize, spine_cache_cap: usize, coalesce: bool) -> Engine {
    let model: Arc<dyn EpsModel> = Arc::new(GmmEps::new(make_gmm("church")));
    Engine::new(
        Arc::new(NativeFactory::new(model, Solver::Ddim)),
        EngineConfig { workers, spine_cache_cap, coalesce, ..EngineConfig::default() },
    )
}

fn vanilla(x0: &[f32], spec: &SamplerSpec) -> Vec<f32> {
    let model: Arc<dyn EpsModel> = Arc::new(GmmEps::new(make_gmm("church")));
    spec.run(&NativeBackend::new(model, Solver::Ddim), x0).sample
}

#[test]
fn cached_repeat_is_bitwise_identical_and_cheaper() {
    let eng = engine(2, 8, false);
    let x0 = prior_sample(64, 1);
    let spec = SamplerSpec::srds(36).with_tol(1e-4).with_seed(1);

    let fresh = eng.run(&x0, &spec);
    assert_eq!(fresh.sample, vanilla(&x0, &spec), "fresh run vs solo vanilla");

    let warm = eng.run(&x0, &spec);
    assert_eq!(warm.sample, fresh.sample, "warm start changed the answer");
    assert_eq!(warm.stats.iters, fresh.stats.iters, "same refinement trajectory");
    assert!(
        warm.stats.eff_serial_evals < fresh.stats.eff_serial_evals,
        "the cached spine must shorten the serial path ({} vs {})",
        warm.stats.eff_serial_evals,
        fresh.stats.eff_serial_evals
    );
    assert!(
        warm.stats.total_evals < fresh.stats.total_evals,
        "a warm start must not redo the coarse sweep's evals"
    );

    let st = eng.stats();
    assert_eq!(st.cache_misses, 1, "only the first run misses");
    assert_eq!(st.cache_hits, 1, "the repeat hits");

    // A different seed is a different shared-work identity: fresh run,
    // fresh miss, still exact.
    let x1 = prior_sample(64, 2);
    let other = spec.clone().with_seed(2);
    let out = eng.run(&x1, &other);
    assert_eq!(out.sample, vanilla(&x1, &other));
    assert_eq!(eng.stats().cache_misses, 2);
}

#[test]
fn coalesced_duplicates_fan_out_one_bitwise_run() {
    // Four identical concurrent submissions on a coalescing engine
    // (cache off, to isolate the dedupe table): one resident run, four
    // bit-identical replies, three coalesced.
    let eng = engine(1, 0, true);
    let x0 = prior_sample(64, 3);
    // tol 0 + a fixed iteration count keeps the task resident across
    // many worker round trips, so the duplicates provably arrive while
    // it is in flight.
    let spec = SamplerSpec::srds(100).with_tol(0.0).with_max_iters(8).with_seed(3);
    let want = vanilla(&x0, &spec);

    let handles: Vec<_> = (0..4).map(|_| eng.submit(x0.clone(), spec.clone())).collect();
    for (i, rx) in handles.into_iter().enumerate() {
        let got = rx.recv().expect("engine reply");
        assert_eq!(got.sample, want, "follower {i} diverged from the solo run");
    }

    let st = eng.stats();
    assert_eq!(st.coalesced, 3, "three duplicates rode the resident task");
    let lane = st.class(QosClass::Standard);
    assert_eq!(lane.submitted, 4, "every duplicate counts as a request");
    assert_eq!(lane.completed, 4, "every duplicate gets its own completion");
    assert_eq!(lane.active(), 0);
    assert_eq!(st.active_tasks, 0);
}

#[test]
fn coalesced_follower_survives_a_dying_sibling() {
    // The coalesced-cancellation contract: two requests share one task;
    // the first client dies mid-run. The survivor must still receive
    // the full bit-identical output, and only the dead request is
    // counted aborted.
    let eng = engine(1, 0, true);
    let x0 = prior_sample(64, 4);
    let spec = SamplerSpec::srds(100).with_tol(0.0).with_max_iters(8).with_seed(4);

    let doomed_alive = Arc::new(AtomicBool::new(true));
    let (doomed_tx, doomed_rx) = channel::<Vec<f32>>();
    eng.submit_with_alive(x0.clone(), spec.clone(), doomed_alive.clone(), move |out, _| {
        let _ = doomed_tx.send(out.sample);
    });
    let (tx, rx) = channel::<Vec<f32>>();
    eng.submit_with_alive(x0.clone(), spec.clone(), Arc::new(AtomicBool::new(true)), move |out, _| {
        let _ = tx.send(out.sample);
    });
    // Kill the first client while the shared task runs; the dispatcher
    // reaps on its next event sweep (the task's own row completions
    // keep the loop turning — no co-tenant churn needed).
    doomed_alive.store(false, Ordering::Relaxed);

    let survivor = rx.recv().expect("surviving follower must still be answered");
    assert_eq!(survivor, vanilla(&x0, &spec), "survivor's output is the solo run's");
    assert!(doomed_rx.try_recv().is_err(), "a dead client must never get a reply");

    let st = eng.stats();
    let lane = st.class(QosClass::Standard);
    assert_eq!(lane.submitted, 2);
    assert_eq!(lane.aborted, 1, "exactly the dead follower aborts");
    assert_eq!(lane.completed, 1, "exactly the survivor completes");
    assert_eq!(lane.active(), 0, "the shared task left the table");
    assert_eq!(st.active_tasks, 0);
}

#[test]
fn eviction_is_lru_and_spares_higher_qos_classes() {
    // cap = 2: the third distinct spine evicts, and the victim is the
    // lowest-QoS entry (batch before standard before interactive),
    // not simply the oldest.
    let eng = engine(2, 2, false);
    let sv = |n: usize, seed: u64, class: QosClass| {
        (prior_sample(64, seed), SamplerSpec::srds(n).with_tol(1e-4).with_seed(seed).with_priority(class))
    };
    let (xa, a) = sv(25, 20, QosClass::Interactive);
    let (xb, b) = sv(34, 21, QosClass::Batch);
    let (xc, c) = sv(49, 22, QosClass::Standard);

    eng.run(&xa, &a); // miss, insert {A}
    eng.run(&xb, &b); // miss, insert {A, B} — cache full
    eng.run(&xc, &c); // miss, insert — victim must be B (batch class)
    let out = eng.run(&xa, &a); // A survived eviction: hit
    assert_eq!(out.sample, vanilla(&xa, &a), "warm repeat after eviction churn is exact");
    eng.run(&xb, &b); // B was the victim: miss, re-insert (evicts C)

    let st = eng.stats();
    assert_eq!(st.cache_misses, 4, "A, B, C first runs plus B's re-run miss");
    assert_eq!(st.cache_hits, 1, "only A's repeat hits");
    assert_eq!(st.cache_evictions, 2, "C's insert evicted B; B's re-insert evicted C");
}

#[test]
fn bounded_cache_cannot_leak_buffers_under_spec_churn() {
    // 60 distinct shared-work identities through a cap-2 cache: with
    // n=25 (a 5-block spine) unbounded retention would pin ~300 state
    // buffers; the LRU must keep the steady-state live set down at
    // straggler-batch scale (same bound family as pool_soak.rs).
    let eng = engine(2, 2, false);
    for seed in 0..60u64 {
        let x0 = prior_sample(64, 2000 + seed);
        let spec = SamplerSpec::srds(25).with_tol(1e-4).with_seed(2000 + seed);
        let out = eng.run(&x0, &spec);
        assert!(out.stats.total_evals > 0);
    }
    let st = eng.stats();
    assert_eq!(st.cache_misses, 60, "every identity is distinct");
    assert_eq!(st.cache_hits, 0);
    assert_eq!(st.cache_evictions, 58, "every insert past cap evicts exactly one");
    let live = eng.pool().stats().live;
    assert!(
        live <= 160,
        "{live} buffers live after churn — the cache must evict spines, not retain them all"
    );
}
