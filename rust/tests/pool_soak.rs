//! Soak test for the zero-copy state-buffer pool: waves of concurrent
//! engine requests must (a) keep the pool's high-water mark bounded (no
//! leak — every StateBuf returns to the pool when its last owner drops)
//! and (b) stop allocating once warm — after the warm-up waves,
//! `pool_misses` stays flat while `pool_hits` keeps climbing.

use srds::batching::BatchPolicy;
use srds::coordinator::{prior_sample, SamplerSpec};
use srds::data::make_gmm;
use srds::exec::{Engine, EngineConfig, NativeFactory};
use srds::model::{EpsModel, GmmEps};
use srds::solvers::Solver;
use std::sync::Arc;

fn engine(workers: usize) -> Engine {
    let model: Arc<dyn EpsModel> = Arc::new(GmmEps::new(make_gmm("church")));
    Engine::new(
        Arc::new(NativeFactory::new(model, Solver::Ddim)),
        EngineConfig { workers, batch: BatchPolicy::default(), ..EngineConfig::default() },
    )
}

/// One wave: `conc` concurrent SRDS requests (mixed sizes, so buffers of
/// one dim bucket churn through many owners), all awaited.
fn wave(eng: &Engine, conc: u64, base_seed: u64) {
    let handles: Vec<_> = (0..conc)
        .map(|i| {
            let seed = base_seed + i;
            let spec = SamplerSpec::srds(25 + 11 * (i as usize % 3))
                .with_tol(1e-4)
                .with_seed(seed);
            eng.submit(prior_sample(64, seed), spec)
        })
        .collect();
    for h in handles {
        h.recv().expect("engine reply");
    }
}

#[test]
fn pool_high_water_stays_bounded_and_hits_dominate() {
    let eng = engine(3);
    let conc = 6u64;

    // Warm-up: the first waves populate the free lists.
    for w in 0..4 {
        wave(&eng, conc, 100 * w);
    }
    let warm = eng.stats();
    assert!(warm.pool_misses > 0, "states do come from the pool");

    // Soak: many more identical waves.
    for w in 4..12 {
        wave(&eng, conc, 100 * w);
    }
    let end = eng.stats();

    // (a) No leak: liveness is bounded by the per-wave working set, so
    // the high-water mark must not keep climbing wave over wave. The
    // theoretical peak is conc × (full SRDS grid + transient rows); n=47
    // → m=7, max_iters=7 → 3·8·8 = 192 states per request.
    let bound = conc as usize * 250;
    assert!(
        end.pool_high_water <= bound,
        "pool high water {} exceeds working-set bound {bound} (leak?)",
        end.pool_high_water
    );

    // (b) Steady state: warm waves stop allocating. Straggler rows that
    // complete after their request finalizes can check a buffer out at
    // an unlucky instant, so allow a small residue rather than exactly
    // zero fresh slabs over 8 waves.
    let fresh = end.pool_misses - warm.pool_misses;
    let recycled = end.pool_hits - warm.pool_hits;
    assert!(
        fresh <= 32,
        "8 post-warm-up waves allocated {fresh} fresh buffers (expected ~0)"
    );
    assert!(
        recycled > 50 * (fresh + 1),
        "pool hits ({recycled}) should dominate misses ({fresh}) after warm-up"
    );

    // All buffers returned: nothing substantial is live once every
    // reply arrived. Straggler batches (rows already on a worker when
    // their request finalized) may briefly hold row + output buffers,
    // bounded by workers × max bucket × 2.
    let live = eng.pool().stats().live;
    assert!(live <= 256, "{live} buffers still checked out after the soak");
}

#[test]
fn mixed_tenants_recycle_through_one_pool() {
    // Heterogeneous tasks — an SRDS grid machine and a sequential chain
    // in flight at once — share the one engine-wide pool.
    let eng = engine(2);
    let x0 = prior_sample(64, 7);
    let srds_handle = eng.submit(x0.clone(), SamplerSpec::srds(36).with_tol(1e-4).with_seed(7));
    let seq_handle = eng.submit(x0, SamplerSpec::sequential(25).with_seed(7));
    let seq = seq_handle.recv().expect("engine reply");
    srds_handle.recv().expect("engine reply");
    assert!(seq.stats.total_evals > 0);
    assert!(seq.stats.engine_rows > 0, "the chain ran as engine rows");
    let st = eng.stats();
    assert!(st.pool_hits + st.pool_misses > 0, "both tenants drew from the pool");
    assert!(st.pool_high_water > 0);
    assert_eq!(st.active_tasks, 0, "task table drained");
}
