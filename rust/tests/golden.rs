//! Golden tests pinning the three layers together:
//!
//! 1. AOT HLO artifacts (L2/L1, compiled by `make artifacts`) loaded and
//!    executed through PJRT reproduce the python-computed golden vectors.
//! 2. The native rust solver steps match the same goldens (so native and
//!    PJRT paths are interchangeable inside the coordinator).
//! 3. Cross-language substrate agreement: dataset parameters and the
//!    schedule grid match `datasets_golden.json` / `schedule_golden.json`.
//!
//! Requires `make artifacts`; tests self-skip when the directory is absent
//! (plain `cargo test` before artifacts are built still passes).

use srds::data::{make_gmm, rng::SplitMix64, PIXEL_DATASETS};
use srds::json;
use srds::model::{EpsModel, GmmEps, SmallDenoiser};
use srds::runtime::{PjrtBackend, PjrtRuntime};
use srds::solvers::{NativeBackend, Solver, StepBackend, StepRequest};
use std::sync::Arc;

fn artifacts_ready() -> bool {
    srds::artifacts_dir().join("manifest.json").exists()
}

fn load_golden(name: &str) -> Option<json::Value> {
    let p = srds::artifacts_dir().join("golden").join(format!("{name}.json"));
    let text = std::fs::read_to_string(p).ok()?;
    Some(json::parse(&text).expect("golden json"))
}

fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
}

/// Native backend for a manifest model name.
fn native_backend(model: &str, solver: Solver) -> NativeBackend {
    let m: Arc<dyn EpsModel> = if model == "small_denoiser" {
        Arc::new(SmallDenoiser::new(256))
    } else {
        Arc::new(GmmEps::new(make_gmm(model.trim_start_matches("gmm_"))))
    };
    NativeBackend::new(m, solver)
}

fn golden_step_request<'a>(
    g: &json::Value,
    x: &'a mut Vec<f32>,
    sf: &'a mut Vec<f32>,
    st: &'a mut Vec<f32>,
    mask: &'a mut Vec<f32>,
    guided: bool,
) -> (StepRequest<'a>, Vec<f32>) {
    let inputs = g.req("inputs").unwrap();
    *x = inputs.req("x").unwrap().as_f32_vec().unwrap();
    *sf = inputs.req("s_from").unwrap().as_f32_vec().unwrap();
    *st = inputs.req("s_to").unwrap().as_f32_vec().unwrap();
    let w = inputs
        .get("w")
        .and_then(|v| v.as_f32_vec())
        .map(|v| v[0])
        .unwrap_or(0.0);
    let m = if guided {
        *mask = inputs.req("mask").unwrap().as_f32_vec().unwrap();
        Some(mask.as_slice())
    } else {
        None
    };
    let expect = g.req("output").unwrap().as_f32_vec().unwrap();
    (
        StepRequest { x, s_from: sf, s_to: st, mask: m, guidance: w, seeds: &[0] },
        expect,
    )
}

#[test]
fn pjrt_executes_every_b1_artifact_to_golden() {
    if !artifacts_ready() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let rt = PjrtRuntime::open_default().expect("open runtime");
    let mut checked = 0;
    for meta in rt.manifest().artifacts.clone() {
        if meta.batch != 1 || meta.solver == "ddpm" {
            continue; // ddpm goldens exercise the noise input separately
        }
        let Some(g) = load_golden(&meta.name) else { continue };
        let be = PjrtBackend::new(&rt, &meta.model, meta.solver_enum().unwrap()).unwrap();
        let (mut x, mut sf, mut st, mut mask) = (vec![], vec![], vec![], vec![]);
        let (req, expect) =
            golden_step_request(&g, &mut x, &mut sf, &mut st, &mut mask, meta.guided);
        // Exercise the write-into contract directly against the recorded
        // python-side goldens (not via the allocating wrapper).
        let mut out = vec![0.0f32; expect.len()];
        be.step_into(&req, &mut out);
        let d = max_abs_diff(&out, &expect);
        assert!(d < 1e-4, "{}: pjrt vs golden max diff {d}", meta.name);
        checked += 1;
    }
    assert!(checked >= 5, "expected several artifacts, checked {checked}");
}

#[test]
fn native_matches_golden_vectors() {
    if !artifacts_ready() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let rt_manifest_path = srds::artifacts_dir().join("manifest.json");
    let manifest = srds::runtime::Manifest::load(&rt_manifest_path).unwrap();
    let mut checked = 0;
    for meta in &manifest.artifacts {
        if meta.batch != 1 || meta.solver == "ddpm" {
            continue;
        }
        let Some(g) = load_golden(&meta.name) else { continue };
        let be = native_backend(&meta.model, meta.solver_enum().unwrap());
        let (mut x, mut sf, mut st, mut mask) = (vec![], vec![], vec![], vec![]);
        let (req, expect) =
            golden_step_request(&g, &mut x, &mut sf, &mut st, &mut mask, meta.guided);
        // step_into against the recorded goldens, as for PJRT above.
        let mut out = vec![0.0f32; expect.len()];
        be.step_into(&req, &mut out);
        let d = max_abs_diff(&out, &expect);
        // Native is f32 like the artifact but op order differs slightly.
        assert!(d < 5e-3, "{}: native vs golden max diff {d}", meta.name);
        checked += 1;
    }
    assert!(checked >= 5, "checked {checked}");
}

#[test]
fn ddpm_noise_path_agrees_native_vs_pjrt() {
    if !artifacts_ready() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let rt = PjrtRuntime::open_default().unwrap();
    let model = "gmm_latent_cond";
    if rt.manifest().steps_for(model, "ddpm").is_empty() {
        return;
    }
    let pjrt = PjrtBackend::new(&rt, model, Solver::Ddpm).unwrap();
    let native = native_backend(model, Solver::Ddpm);
    let d = pjrt.dim();
    let mut rng = SplitMix64::new(99);
    let x = rng.normals_f32(d);
    let mask = vec![1.0f32; pjrt.k()];
    let req = StepRequest {
        x: &x,
        s_from: &[0.3],
        s_to: &[0.35],
        mask: Some(&mask),
        guidance: 7.5,
        seeds: &[1234],
    };
    let a = pjrt.step(&req);
    let b = native.step(&req);
    let diff = max_abs_diff(&a, &b);
    assert!(diff < 5e-3, "ddpm pjrt vs native: {diff}");
}

#[test]
fn batched_artifact_matches_per_row() {
    if !artifacts_ready() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let rt = PjrtRuntime::open_default().unwrap();
    let be = PjrtBackend::new(&rt, "gmm_church", Solver::Ddim).unwrap();
    let d = be.dim();
    let b = 11; // exercises 8 + padded-1 bucket plan
    let mut rng = SplitMix64::new(5);
    let x = rng.normals_f32(b * d);
    let s_from: Vec<f32> = (0..b).map(|i| i as f32 / b as f32 * 0.9).collect();
    let s_to: Vec<f32> = s_from.iter().map(|s| s + 0.05).collect();
    let seeds = vec![0u64; b];
    let full = be.step(&StepRequest {
        x: &x,
        s_from: &s_from,
        s_to: &s_to,
        mask: None,
        guidance: 0.0,
        seeds: &seeds,
    });
    for i in 0..b {
        let row = be.step(&StepRequest {
            x: &x[i * d..(i + 1) * d],
            s_from: &s_from[i..=i],
            s_to: &s_to[i..=i],
            mask: None,
            guidance: 0.0,
            seeds: &seeds[i..=i],
        });
        let diff = max_abs_diff(&full[i * d..(i + 1) * d], &row);
        assert!(diff < 1e-5, "row {i} diff {diff}");
    }
}

/// Drive one backend through a batch of varied step_into calls (dirty
/// scratch, shrinking/growing batches) and pin every output bitwise
/// against a freshly-constructed backend's first call. This isolates the
/// scratch-reuse class of regression: a reused backend whose internal
/// scratch leaks state across calls or batch shapes diverges from a
/// fresh instance here. (It is deliberately *not* the recorded-output
/// pin — `step` is a wrapper over `step_into`, so comparing them cannot
/// catch a semantic change made to both. The recorded pins are
/// `native_matches_golden_vectors` / `pjrt_executes_every_b1_artifact_to_golden`
/// above, which run `step_into` against python-side golden JSON.)
fn pin_step_into<F: Fn() -> B, B: StepBackend>(make: F, label: &str) {
    let d = make().dim();
    let mut rng = SplitMix64::new(77);
    for trial in 0..2 {
        let reused = make();
        for b in [3usize, 1, 5, 2] {
            let x = rng.normals_f32(b * d);
            let s_from: Vec<f32> =
                (0..b).map(|i| 0.04 + 0.13 * i as f32 + 0.01 * trial as f32).collect();
            let s_to: Vec<f32> = s_from.iter().map(|s| s + 0.06).collect();
            let seeds: Vec<u64> = (trial as u64 * 100..trial as u64 * 100 + b as u64).collect();
            let req = StepRequest {
                x: &x,
                s_from: &s_from,
                s_to: &s_to,
                mask: None,
                guidance: 0.0,
                seeds: &seeds,
            };
            let mut out = vec![0.0f32; b * d];
            reused.step_into(&req, &mut out);
            let fresh = make().step(&req);
            assert_eq!(out, fresh, "{label} b={b}: dirty scratch diverged from a fresh backend");
        }
    }
}

#[test]
fn step_into_scratch_reuse_is_bitwise_stable_native_all_solvers() {
    for solver in Solver::ALL {
        pin_step_into(
            || native_backend("gmm_church", solver),
            &format!("native/{}", solver.name()),
        );
    }
}

#[test]
fn step_into_scratch_reuse_is_bitwise_stable_pjrt_all_solvers() {
    if !artifacts_ready() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let rt = PjrtRuntime::open_default().expect("open runtime");
    for solver in Solver::ALL {
        if rt.manifest().steps_for("gmm_church", solver.name()).is_empty() {
            continue;
        }
        pin_step_into(
            || PjrtBackend::new(&rt, "gmm_church", solver).expect("load backend"),
            &format!("pjrt/{}", solver.name()),
        );
    }
}

#[test]
fn dataset_params_match_python() {
    if !artifacts_ready() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let text =
        std::fs::read_to_string(srds::artifacts_dir().join("datasets_golden.json")).unwrap();
    let v = json::parse(&text).unwrap();
    for name in PIXEL_DATASETS.iter().chain(["latent_cond", "toy2d"].iter()) {
        let g = make_gmm(name);
        let gj = v.req(name).unwrap();
        assert_eq!(gj.req("dim").unwrap().as_usize().unwrap(), g.dim(), "{name} dim");
        let means = gj.req("means").unwrap().as_f32_vec().unwrap();
        assert_eq!(means.len(), g.means.len());
        let d = max_abs_diff(&means, &g.means);
        assert!(d < 1e-6, "{name}: means diff {d}");
        let sig = gj.req("sigmas").unwrap().as_f32_vec().unwrap();
        assert!(max_abs_diff(&sig, &g.sigmas) < 1e-6, "{name} sigmas");
        let w = gj.req("weights").unwrap().as_f32_vec().unwrap();
        assert!(max_abs_diff(&w, &g.weights) < 1e-6, "{name} weights");
    }
}

#[test]
fn schedule_matches_python() {
    if !artifacts_ready() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let text =
        std::fs::read_to_string(srds::artifacts_dir().join("schedule_golden.json")).unwrap();
    let v = json::parse(&text).unwrap();
    let s = v.req("s").unwrap().as_f32_vec().unwrap();
    let ab = v.req("alpha_bar").unwrap().as_f32_vec().unwrap();
    let lam = v.req("lam").unwrap().as_f32_vec().unwrap();
    for i in 0..s.len() {
        let mine = srds::schedule::alpha_bar(s[i]);
        assert!(
            (mine - ab[i]).abs() < 1e-6,
            "alpha_bar(s={}) {} vs {}",
            s[i],
            mine,
            ab[i]
        );
        let ml = srds::schedule::lam(s[i]);
        let rel = (ml - lam[i]).abs() / lam[i].abs().max(1.0);
        assert!(rel < 1e-4, "lam(s={}) {} vs {}", s[i], ml, lam[i]);
    }
}
