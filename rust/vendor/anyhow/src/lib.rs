//! Minimal offline stand-in for the `anyhow` crate, providing exactly
//! the API surface this repository uses: [`Error`], [`Result`], the
//! [`anyhow!`] / [`bail!`] / [`ensure!`] macros, and the [`Context`]
//! extension trait. Error chains are flattened into the message at
//! construction time, so both `{e}` and `{e:#}` render the full
//! `context: cause` chain like upstream's alternate formatting does.
//!
//! Like upstream, [`Error`] deliberately does **not** implement
//! `std::error::Error` — that is what allows the blanket
//! `From<E: std::error::Error>` conversion behind `?`.

use std::fmt;

/// A flattened dynamic error.
pub struct Error {
    msg: String,
}

impl Error {
    /// Construct from anything displayable.
    pub fn msg<M: fmt::Display>(msg: M) -> Self {
        Error { msg: msg.to_string() }
    }

    /// Prepend a context line (upstream renders chains as
    /// `context: cause` under `{:#}`; here the chain is eager).
    pub fn context<C: fmt::Display>(self, context: C) -> Self {
        Error { msg: format!("{context}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Self {
        Error { msg: e.to_string() }
    }
}

/// `anyhow::Result<T>` — `std::result::Result` with a defaulted error.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach lazy context to a fallible value.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error { msg: format!("{context}: {e}") })
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error { msg: format!("{}: {e}", f()) })
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an error.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: {}", stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        Err(anyhow!("boom {}", 42))
    }

    #[test]
    fn macros_and_context_render_chains() {
        let e = fails().with_context(|| "outer").unwrap_err();
        assert_eq!(format!("{e}"), "outer: boom 42");
        assert_eq!(format!("{e:#}"), "outer: boom 42");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse() -> Result<i32> {
            Ok("12".parse::<i32>()?)
        }
        assert_eq!(parse().unwrap(), 12);
    }

    #[test]
    fn ensure_and_bail() {
        fn check(v: i32) -> Result<i32> {
            ensure!(v > 0, "must be positive, got {v}");
            if v > 100 {
                bail!("too big");
            }
            Ok(v)
        }
        assert!(check(5).is_ok());
        assert!(format!("{}", check(-1).unwrap_err()).contains("positive"));
        assert!(format!("{}", check(200).unwrap_err()).contains("too big"));
    }
}
