//! Offline stub of the `xla` crate (xla-rs), exposing exactly the API
//! surface `srds::runtime` uses. The container this repository builds in
//! has no XLA/PJRT toolchain, so every runtime entry point returns a
//! descriptive error instead: `PjRtClient::cpu()` fails, which makes
//! `PjrtRuntime::open` fail, which every caller already handles by
//! falling back to the native backend (and the PJRT integration tests
//! self-skip when artifacts are absent).
//!
//! To run the AOT artifacts for real, point the `xla` dependency in
//! `rust/Cargo.toml` at the actual xla-rs crate with `xla_extension`
//! installed; this stub is call-compatible with the subset used.

use std::fmt;
use std::path::Path;

/// Stub error type (converts into `anyhow::Error` through `?`).
#[derive(Debug, Clone)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "{what}: XLA/PJRT unavailable (stub `xla` crate; swap rust/vendor/xla \
         for the real xla-rs + xla_extension to run AOT artifacts)"
    )))
}

/// Host-side literal: enough structure to build inputs; execution never
/// happens in the stub, so conversions only need to typecheck.
#[derive(Debug, Clone)]
pub struct Literal {
    data: Vec<f32>,
    dims: Vec<i64>,
}

impl Literal {
    pub fn scalar(v: f32) -> Literal {
        Literal { data: vec![v], dims: vec![] }
    }

    pub fn vec1(data: &[f32]) -> Literal {
        Literal { data: data.to_vec(), dims: vec![data.len() as i64] }
    }

    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        if want != self.data.len() as i64 {
            return Err(Error(format!(
                "reshape {:?} -> {dims:?}: element count mismatch",
                self.dims
            )));
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
    }

    pub fn to_tuple1(self) -> Result<Literal> {
        Ok(self)
    }

    pub fn to_vec<T: FromF32>(&self) -> Result<Vec<T>> {
        Ok(self.data.iter().map(|&v| T::from_f32(v)).collect())
    }
}

/// Element conversion for [`Literal::to_vec`].
pub trait FromF32 {
    fn from_f32(v: f32) -> Self;
}

impl FromF32 for f32 {
    fn from_f32(v: f32) -> Self {
        v
    }
}

impl FromF32 for f64 {
    fn from_f32(v: f32) -> Self {
        v as f64
    }
}

pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    /// Always fails in the stub — no PJRT plugin is linked.
    pub fn cpu() -> Result<Self> {
        unavailable("PjRtClient::cpu")
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }
}

pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _inputs: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: impl AsRef<Path>) -> Result<Self> {
        unavailable("HloModuleProto::from_text_file")
    }
}

pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation { _private: () }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let e = PjRtClient::cpu().err().expect("stub must fail");
        assert!(format!("{e}").contains("unavailable"));
    }

    #[test]
    fn literals_are_buildable() {
        let l = Literal::vec1(&[1.0, 2.0, 3.0, 4.0]).reshape(&[2, 2]).unwrap();
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(Literal::vec1(&[1.0]).reshape(&[3]).is_err());
        let _ = Literal::scalar(0.5);
    }
}
