#!/usr/bin/env python3
"""Bench-regression gate for CI.

Compares a fresh bench JSON report against a committed baseline and
exits non-zero on regression beyond tolerance. Two baseline shapes:

* **Serving** (`BENCH_serving.json`, one report object): throughput
  keys (`rps`) and ratio keys (`*_rate`, e.g. the repeat section's
  cache `hit_rate`) must not drop more than 20% below baseline;
  latency keys (`*_ms`) must not rise more than 20% above baseline.
* **Hot path** (`BENCH_hotpath.json`, detected by its top-level
  `hot_path` list): the `cargo bench --bench hot_path` report is one
  JSON line per (dim, batch) configuration. Baseline entries are
  matched by (dim, batch); `steps_per_sec` is a floor with the same
  20% tolerance, while `allocs_per_step` is gated **exactly** — any
  value above the baseline's (normally 0) fails with no tolerance,
  because a single allocation per step is a broken zero-copy
  invariant, not a perf regression.

Only leaves present in the *baseline* are checked, so the baseline
doubles as the contract: seed it with conservative floors, tighten it as
real measurements accumulate. Keys starting with "_" are comments.

Usage:
    python3 ci/bench_gate.py BENCH_serving.json serving_output.json
    python3 ci/bench_gate.py BENCH_hotpath.json hot_path_output.json

To refresh a baseline after an intentional perf change:
    (cd rust && cargo bench --bench serving) | tail -n 1 > /tmp/serving.json
    (cd rust && cargo bench --bench hot_path) > /tmp/hot_path.json
then fold the numbers you want to pin into the committed baseline.
"""

import json
import sys

TOLERANCE = 0.20


def load_report(path):
    """The bench prints one JSON object per line; runner chatter may
    surround it. Take the last line that parses as the serving report."""
    report = None
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line.startswith("{"):
                continue
            try:
                obj = json.loads(line)
            except json.JSONDecodeError:
                continue
            if obj.get("bench") == "serving_throughput" or report is None:
                report = obj
    if report is None:
        sys.exit(f"error: no JSON report found in {path}")
    return report


def load_report_lines(path):
    """All parseable JSON-object lines of a multi-line bench report."""
    objs = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line.startswith("{"):
                continue
            try:
                objs.append(json.loads(line))
            except json.JSONDecodeError:
                continue
    return objs


def gate_hot_path(baseline, report_path, failures, checked):
    """Hot-path mode: match baseline entries by (dim, batch); floor-gate
    steps_per_sec, exact-gate allocs_per_step (the zero-copy invariant
    gets no tolerance)."""
    lines = [o for o in load_report_lines(report_path) if o.get("bench") == "hot_path"]
    for base in baseline["hot_path"]:
        dim, batch = base["dim"], base["batch"]
        where = f"hot_path[dim={dim},batch={batch}]"
        cur = next(
            (o for o in lines if o.get("dim") == dim and o.get("batch") == batch),
            None,
        )
        if cur is None:
            failures.append(f"{where}: missing from bench output")
            continue
        if "steps_per_sec" in base:
            floor = base["steps_per_sec"] * (1.0 - TOLERANCE)
            sps = cur.get("steps_per_sec", 0.0)
            if sps < floor:
                failures.append(
                    f"{where}: {sps:.0f} steps/sec regressed >"
                    f"{TOLERANCE:.0%} below baseline {base['steps_per_sec']:.0f}")
            else:
                checked.append(f"{where}: {sps:.0f} steps/sec (floor {floor:.0f})")
        if "allocs_per_step" in base:
            cap = base["allocs_per_step"]
            allocs = cur.get("allocs_per_step", float("inf"))
            if allocs > cap:
                failures.append(
                    f"{where}: allocs_per_step {allocs} > {cap} — "
                    "steady-state steps must not allocate (no tolerance)")
            else:
                checked.append(f"{where}: allocs_per_step {allocs} (cap {cap})")


def walk(baseline, current, path, failures, checked):
    if isinstance(baseline, dict):
        for key, base_val in baseline.items():
            if key.startswith("_"):
                continue
            if not isinstance(current, dict) or key not in current:
                failures.append(f"{'.'.join(path + [key])}: missing from bench output")
                continue
            walk(base_val, current[key], path + [key], failures, checked)
    elif isinstance(baseline, list):
        if not isinstance(current, list):
            failures.append(f"{'.'.join(path)}: expected a list in bench output")
            return
        for i, base_val in enumerate(baseline):
            # Match entries by their "shards" level when present (sharded
            # entries also carry a "clients" key, which is the same at
            # every width and would mis-match), then by "clients", else
            # by index.
            level_key = next(
                (k for k in ("shards", "clients")
                 if isinstance(base_val, dict) and k in base_val),
                None,
            )
            if level_key is not None:
                match = next(
                    (c for c in current
                     if isinstance(c, dict)
                     and c.get(level_key) == base_val[level_key]),
                    None,
                )
                if match is None:
                    failures.append(
                        f"{'.'.join(path)}[{level_key}={base_val[level_key]}]: "
                        "missing from bench output")
                    continue
                walk(base_val, match,
                     path + [f"{level_key}={base_val[level_key]}"],
                     failures, checked)
            elif i < len(current):
                walk(base_val, current[i], path + [str(i)], failures, checked)
            else:
                failures.append(f"{'.'.join(path)}[{i}]: missing from bench output")
    elif isinstance(baseline, (int, float)):
        key = path[-1]
        where = ".".join(path)
        if key == "rps" or key.endswith("_rps"):
            floor = baseline * (1.0 - TOLERANCE)
            if current < floor:
                failures.append(
                    f"{where}: throughput {current:.2f} regressed >"
                    f"{TOLERANCE:.0%} below baseline {baseline:.2f}")
            else:
                checked.append(f"{where}: {current:.2f} rps (floor {floor:.2f})")
        elif key.endswith("_rate"):
            # Ratio floors (e.g. the repeat section's cache hit_rate):
            # same 20% relative tolerance as throughput — a cache gone
            # cold is a structural regression, not noise.
            floor = baseline * (1.0 - TOLERANCE)
            if current < floor:
                failures.append(
                    f"{where}: rate {current:.3f} regressed >"
                    f"{TOLERANCE:.0%} below baseline {baseline:.3f}")
            else:
                checked.append(f"{where}: {current:.3f} rate (floor {floor:.3f})")
        elif key.endswith("_ms"):
            ceil = baseline * (1.0 + TOLERANCE)
            if current > ceil:
                failures.append(
                    f"{where}: latency {current:.2f} ms regressed >"
                    f"{TOLERANCE:.0%} above baseline {baseline:.2f}")
            else:
                checked.append(f"{where}: {current:.2f} ms (ceiling {ceil:.2f})")
        # Other numeric leaves (clients, requests, weights) are identity
        # context, not gated metrics.


def main():
    if len(sys.argv) != 3:
        sys.exit(__doc__)
    with open(sys.argv[1]) as f:
        baseline = json.load(f)
    failures, checked = [], []
    if "hot_path" in baseline:
        gate_hot_path(baseline, sys.argv[2], failures, checked)
    else:
        current = load_report(sys.argv[2])
        walk(baseline, current, [], failures, checked)
    if not checked and not failures:
        sys.exit("error: baseline pinned no gated metrics (rps / *_ms leaves)")
    print(f"bench gate: {len(checked) + len(failures)} metrics checked")
    for line in checked:
        print(f"  ok  {line}")
    if failures:
        for line in failures:
            print(f"  FAIL {line}", file=sys.stderr)
        sys.exit(1)
    print("bench gate: no regression beyond tolerance")


if __name__ == "__main__":
    main()
