#!/usr/bin/env python3
"""Bench-regression gate for CI.

Compares a fresh `cargo bench --bench serving` JSON report against the
committed baseline (BENCH_serving.json at the repo root) and exits
non-zero when serving performance regressed beyond tolerance:

* throughput keys (`rps`) must not drop more than 20% below baseline;
* latency keys (`*_ms`) must not rise more than 20% above baseline.

Only leaves present in the *baseline* are checked, so the baseline
doubles as the contract: seed it with conservative floors, tighten it as
real measurements accumulate. Keys starting with "_" are comments.

Usage:
    python3 ci/bench_gate.py BENCH_serving.json serving_output.json

To refresh the baseline after an intentional perf change:
    (cd rust && cargo bench --bench serving) | tail -n 1 > /tmp/serving.json
then fold the numbers you want to pin into BENCH_serving.json.
"""

import json
import sys

TOLERANCE = 0.20


def load_report(path):
    """The bench prints one JSON object per line; runner chatter may
    surround it. Take the last line that parses as the serving report."""
    report = None
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line.startswith("{"):
                continue
            try:
                obj = json.loads(line)
            except json.JSONDecodeError:
                continue
            if obj.get("bench") == "serving_throughput" or report is None:
                report = obj
    if report is None:
        sys.exit(f"error: no JSON report found in {path}")
    return report


def walk(baseline, current, path, failures, checked):
    if isinstance(baseline, dict):
        for key, base_val in baseline.items():
            if key.startswith("_"):
                continue
            if not isinstance(current, dict) or key not in current:
                failures.append(f"{'.'.join(path + [key])}: missing from bench output")
                continue
            walk(base_val, current[key], path + [key], failures, checked)
    elif isinstance(baseline, list):
        if not isinstance(current, list):
            failures.append(f"{'.'.join(path)}: expected a list in bench output")
            return
        for i, base_val in enumerate(baseline):
            # Match points by their "clients" level when present, else by index.
            if isinstance(base_val, dict) and "clients" in base_val:
                match = next(
                    (c for c in current
                     if isinstance(c, dict) and c.get("clients") == base_val["clients"]),
                    None,
                )
                if match is None:
                    failures.append(
                        f"{'.'.join(path)}[clients={base_val['clients']}]: "
                        "missing from bench output")
                    continue
                walk(base_val, match, path + [f"clients={base_val['clients']}"],
                     failures, checked)
            elif i < len(current):
                walk(base_val, current[i], path + [str(i)], failures, checked)
            else:
                failures.append(f"{'.'.join(path)}[{i}]: missing from bench output")
    elif isinstance(baseline, (int, float)):
        key = path[-1]
        where = ".".join(path)
        if key == "rps" or key.endswith("_rps"):
            floor = baseline * (1.0 - TOLERANCE)
            if current < floor:
                failures.append(
                    f"{where}: throughput {current:.2f} regressed >"
                    f"{TOLERANCE:.0%} below baseline {baseline:.2f}")
            else:
                checked.append(f"{where}: {current:.2f} rps (floor {floor:.2f})")
        elif key.endswith("_ms"):
            ceil = baseline * (1.0 + TOLERANCE)
            if current > ceil:
                failures.append(
                    f"{where}: latency {current:.2f} ms regressed >"
                    f"{TOLERANCE:.0%} above baseline {baseline:.2f}")
            else:
                checked.append(f"{where}: {current:.2f} ms (ceiling {ceil:.2f})")
        # Other numeric leaves (clients, requests, weights) are identity
        # context, not gated metrics.


def main():
    if len(sys.argv) != 3:
        sys.exit(__doc__)
    with open(sys.argv[1]) as f:
        baseline = json.load(f)
    current = load_report(sys.argv[2])
    failures, checked = [], []
    walk(baseline, current, [], failures, checked)
    if not checked and not failures:
        sys.exit("error: baseline pinned no gated metrics (rps / *_ms leaves)")
    print(f"bench gate: {len(checked) + len(failures)} metrics checked")
    for line in checked:
        print(f"  ok  {line}")
    if failures:
        for line in failures:
            print(f"  FAIL {line}", file=sys.stderr)
        sys.exit(1)
    print("bench gate: no regression beyond tolerance")


if __name__ == "__main__":
    main()
